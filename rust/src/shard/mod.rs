//! Multi-FPGA sharding: partition one DNN across a cluster of boards.
//!
//! DNNExplorer's paradigm splits a network into a layer-dedicated
//! pipelined prefix plus a generic suffix on *one* FPGA. This subsystem
//! lifts the paradigm to N (possibly heterogeneous) boards: the network
//! is cut into **contiguous pipeline stages**, each stage mapped to one
//! board — or *replicated* across `r` identical boards with round-robin
//! frame interleaving ([`ShardConfig::max_replicas`]) — each board runs
//! the full single-FPGA DSE on its stage's sub-network (so every board
//! gets its own RAV — pipeline prefix + generic suffix *within* its
//! shard), and the activation tensor crossing each cut is charged
//! against an inter-board [`LinkModel`].
//!
//! * [`partition`] — the cut-point planner: a dynamic program over
//!   `(layer range, device, replication)` cells that maximizes
//!   end-to-end throughput (min over effective stage rates, per-cut
//!   topology ceilings, and — on switch fabrics — the shared bisection
//!   term), reusing the [`crate::dse::cache::EvalCache`] per
//!   (sub-network, device) so repeated ranges — guaranteed across the
//!   DP cells, replication factors, and board counts — are explored
//!   once. Replicas of a stage run the *same* explored design, so the
//!   replication dimension adds no DSE cost.
//! * [`link`] — link presets and cut-tensor accounting on top of the
//!   [`crate::perfmodel::link`] model.
//!
//! Cuts are priced through the configured board interconnect
//! ([`ShardConfig::fabric`] + [`ShardConfig::link`] via
//! [`crate::topo::Topology`]): `p2p`/`mesh` reduce bit-exactly to the
//! uniform link, a `ring` collapses every cut to its single boundary
//! segment, and a `star:<gbps>` switch charges the sum of concurrent
//! cut traffic against its bisection bandwidth.
//!
//! System model ([`crate::perfmodel::interleave`]): a stage replicated
//! `r_s`-wide runs at `r_s · fps_s`; the cut between stages `s` and
//! `s+1` runs over `min(r_s, r_{s+1})` parallel links; steady-state
//! throughput is the min over both families, and single-frame latency —
//! replication-invariant — is `Σ_s latency_s + Σ_cut (L_link +
//! bytes_cut / BW_link)`. The multi-FPGA DSE mode over this planner
//! lives in [`crate::dse::multi`]; serving a plan as a chain of
//! (replica groups of) per-board servers lives in
//! [`crate::coordinator::sharded`]; `tests/sim_vs_model.rs`
//! cross-validates the analytic model against the discrete-event
//! simulator ([`crate::sim::shard`]) and the live pipeline.

pub mod bound;
pub mod link;
pub mod partition;

pub use crate::perfmodel::link::LinkModel;
pub use partition::{partition, PlanStats, Planner, ShardPlan, ShardStage};

use crate::dnn::Precision;
use crate::dse::engine::{ExplorerConfig, Objective};
use crate::dse::pso::PsoParams;
use crate::fpga::FpgaDevice;
use crate::topo::{FabricKind, Topology};

/// Which search strategy the cut-point planner runs. Both modes
/// produce bit-identical [`ShardPlan`]s whenever the Pareto beam cap
/// ([`ShardConfig::fabric_frontier_cap`]) does not bind — pinned by
/// proptest — so the mode is purely a wall-clock knob.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlannerMode {
    /// Evaluate every reachable `(layer range, device, r)` DSE cell up
    /// front (the historical planner) — the reference implementation
    /// the fast path is pinned against, and the bench baseline.
    Exhaustive,
    /// Lazy cell evaluation with branch-and-bound pruning: DP
    /// transitions (and the DSE cells behind them) whose admissible
    /// upper bound cannot beat the incumbent plan are never evaluated
    /// (see `rust/docs/planner.md` for the bound derivation).
    BranchAndBound,
}

impl std::fmt::Display for PlannerMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlannerMode::Exhaustive => write!(f, "exhaustive"),
            PlannerMode::BranchAndBound => write!(f, "bnb"),
        }
    }
}

impl std::str::FromStr for PlannerMode {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "exhaustive" | "naive" => Ok(PlannerMode::Exhaustive),
            "bnb" | "branch-and-bound" | "pruned" => Ok(PlannerMode::BranchAndBound),
            other => Err(format!("unknown planner mode {other:?} (exhaustive|bnb)")),
        }
    }
}

/// Configuration of a sharded exploration: everything an
/// [`ExplorerConfig`] carries except the device (one per board), plus
/// the inter-board link and how the boards are wired together.
#[derive(Debug, Clone)]
pub struct ShardConfig {
    /// The per-port board-to-board link (cable, ring segment, or switch
    /// uplink, per [`ShardConfig::fabric`]).
    pub link: LinkModel,
    /// How the cluster is wired: the planner resolves every cut through
    /// [`crate::topo::Topology`] built from this kind over
    /// [`ShardConfig::link`]. The default ([`FabricKind::PointToPoint`])
    /// reduces bit-exactly to the uniform-link planner.
    pub fabric: FabricKind,
    /// Activation bit-width.
    pub dw: Precision,
    /// Weight bit-width.
    pub ww: Precision,
    /// Pin the batch size (`None` lets each board's DSE explore it).
    pub fixed_batch: Option<usize>,
    pub objective: Objective,
    /// PSO budget for each per-board sub-network exploration.
    pub pso: PsoParams,
    pub seed: u64,
    /// Worker threads for the planner's (range × device) sweep.
    pub threads: usize,
    /// Maximum boards one stage may be replicated across (round-robin
    /// frame interleaving). `1` (the default) restricts the planner to
    /// classic contiguous plans — bit-identical to the pre-replication
    /// planner; replicas must run on identical boards (a contiguous
    /// same-device run of the cluster list).
    pub max_replicas: usize,
    /// Search strategy (see [`PlannerMode`]); bit-identical plans
    /// either way, so the default is the pruned fast path.
    pub planner: PlannerMode,
    /// Beam cap on the per-cell Pareto frontier used on switch fabrics.
    /// Small clusters never hit it; when it binds, the drop count is
    /// surfaced in [`PlanStats::frontier_dropped`] (no silent caps).
    pub fabric_frontier_cap: usize,
}

impl Default for ShardConfig {
    fn default() -> Self {
        Self {
            link: LinkModel::default(),
            fabric: FabricKind::PointToPoint,
            dw: Precision::Int16,
            ww: Precision::Int16,
            fixed_batch: Some(1),
            objective: Objective::Throughput,
            pso: PsoParams::default(),
            seed: 0xD44E,
            threads: 1,
            max_replicas: 1,
            planner: PlannerMode::BranchAndBound,
            fabric_frontier_cap: 128,
        }
    }
}

impl ShardConfig {
    /// The interconnect graph the planner prices cuts against:
    /// [`ShardConfig::fabric`] wired with [`ShardConfig::link`] ports.
    pub fn topology(&self) -> Topology {
        Topology::new(self.link, self.fabric)
    }

    /// The single-board explorer configuration for one device of the
    /// cluster. Swarm threads stay at 1 — the planner parallelizes over
    /// (range, device) cells instead, which is both coarser-grained and
    /// skew-tolerant under the work-stealing schedule.
    pub fn explorer_for(&self, device: &FpgaDevice) -> ExplorerConfig {
        ExplorerConfig {
            device: device.clone(),
            dw: self.dw,
            ww: self.ww,
            fixed_batch: self.fixed_batch,
            objective: self.objective,
            pso: self.pso.clone(),
            seed: self.seed,
            threads: 1,
        }
    }
}
