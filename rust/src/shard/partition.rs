//! Cut-point planner: partition a network into contiguous per-board
//! pipeline stages with a dynamic program over (range, board) cells.
//!
//! Board `b` of a `B`-board cluster runs compute layers `[j_b, j_{b+1})`
//! (plus the non-compute layers trailing them); every cell's sub-network
//! is explored with the full single-FPGA DSE, so each board gets its own
//! RAV. The DP maximizes end-to-end throughput — the min over board
//! rates and link serialization rates — with latency (stage latencies
//! plus hop costs) as the tie-breaker; under
//! [`Objective::Latency`] the two criteria swap.
//!
//! Every (range, device) cell is explored at most once per call (cells
//! repeat across DP rows whenever the cluster repeats a device), and the
//! underlying RAV evaluations are memoized in the shared
//! [`EvalCache`] — so comparing board counts over the same cluster
//! (see [`crate::dse::multi`]) re-explores nothing but the PSO walk.

use std::collections::{BTreeSet, HashMap};

use crate::dnn::Network;
use crate::dse::cache::EvalCache;
use crate::dse::engine::{self, Candidate, Objective};
use crate::fpga::FpgaDevice;
use crate::perfmodel::link::LinkModel;
use crate::shard::link::tensor_bytes;
use crate::shard::ShardConfig;
use crate::util::parallel::parallel_map;

/// One board's slice of a [`ShardPlan`].
#[derive(Debug, Clone)]
pub struct ShardStage {
    /// Board index in the cluster (pipeline order).
    pub board: usize,
    pub device: FpgaDevice,
    /// Compute-layer range `[start, end)` this board runs (indices into
    /// the network's compute layers, in order).
    pub layer_range: (usize, usize),
    /// The board's explored single-FPGA design for its sub-network.
    pub candidate: Candidate,
    /// Activation bytes leaving this stage toward the next board per
    /// frame (0 for the last stage).
    pub egress_bytes: f64,
    /// Frame rate the link sustains for that egress (∞ for the last).
    pub egress_fps: f64,
}

/// A full multi-board partition: stages in pipeline order plus the
/// system-level model outputs.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    pub network: String,
    pub link: LinkModel,
    pub stages: Vec<ShardStage>,
    /// End-to-end steady-state frames/s:
    /// `min(min_b fps_b, min_cut link_fps_cut)`.
    pub throughput_fps: f64,
    /// Whole-network sustained GOP/s at that frame rate.
    pub gops: f64,
    /// Single-frame latency: stage latencies plus hop costs, seconds.
    pub latency_s: f64,
}

impl ShardPlan {
    /// What limits the plan: `board<i>` or `link<i>-><i+1>`.
    pub fn bottleneck(&self) -> String {
        let eps = self.throughput_fps * 1e-9;
        for s in &self.stages {
            if s.candidate.throughput_fps <= self.throughput_fps + eps {
                return format!("board{}", s.board);
            }
            if s.egress_fps <= self.throughput_fps + eps {
                return format!("link{}->{}", s.board, s.board + 1);
            }
        }
        "none".into()
    }

    /// Aligned text rendering (CLI output).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{}: {} boards over {} link\n",
            self.network,
            self.stages.len(),
            self.link
        ));
        out.push_str(&format!(
            "{:<6} {:<8} {:<10} {:<26} {:>9} {:>9} {:>7} {:>7} {:>10}\n",
            "board", "device", "layers", "RAV", "fps", "GOP/s", "DSP", "BRAM", "egress"
        ));
        for s in &self.stages {
            let egress = if s.egress_bytes > 0.0 {
                format!("{:.0} KB", s.egress_bytes / 1024.0)
            } else {
                "-".into()
            };
            out.push_str(&format!(
                "{:<6} {:<8} {:<10} {:<26} {:>9.1} {:>9.1} {:>7.0} {:>7.0} {:>10}\n",
                s.board,
                s.device.name,
                format!("{}..{}", s.layer_range.0, s.layer_range.1),
                format!("{}", s.candidate.rav),
                s.candidate.throughput_fps,
                s.candidate.gops,
                s.candidate.dsp_used,
                s.candidate.bram_used,
                egress,
            ));
        }
        out.push_str(&format!(
            "e2e: {:.1} img/s = {:.1} GOP/s, latency {:.2} ms, bottleneck {}\n",
            self.throughput_fps,
            self.gops,
            self.latency_s * 1e3,
            self.bottleneck()
        ));
        out
    }
}

/// Positions of the compute layers within `net.layers`.
fn compute_positions(net: &Network) -> Vec<usize> {
    net.layers
        .iter()
        .enumerate()
        .filter(|(_, l)| l.is_compute())
        .map(|(i, _)| i)
        .collect()
}

/// Full-layer boundary of compute-layer index `c`: non-compute layers
/// trail the compute layer they follow (a pool stays with its conv).
fn boundary(net: &Network, comp_pos: &[usize], c: usize) -> usize {
    if c == 0 {
        0
    } else if c == comp_pos.len() {
        net.layers.len()
    } else {
        comp_pos[c]
    }
}

/// The sub-network covering compute layers `[c_start, c_end)` of `net`,
/// including the non-compute layers trailing each of them.
pub fn subnetwork(net: &Network, c_start: usize, c_end: usize) -> Network {
    let comp_pos = compute_positions(net);
    assert!(c_start < c_end && c_end <= comp_pos.len(), "bad range {c_start}..{c_end}");
    let lo = boundary(net, &comp_pos, c_start);
    let hi = boundary(net, &comp_pos, c_end);
    let layers = net.layers[lo..hi].to_vec();
    Network {
        name: format!("{}[{}..{}]", net.name, c_start, c_end),
        input: layers[0].input,
        layers,
    }
}

/// Two catalogue devices with identical budgets are the same board type
/// (the planner reuses their DSE cells).
fn same_device(a: &FpgaDevice, b: &FpgaDevice) -> bool {
    a.dsp == b.dsp
        && a.bram18k == b.bram18k
        && a.bandwidth_gbps == b.bandwidth_gbps
        && a.freq_mhz == b.freq_mhz
}

#[derive(Clone, Copy)]
struct Cell {
    fps: f64,
    latency_s: f64,
    /// Start compute-layer index of the last stage in this cell's plan.
    prev_j: usize,
}

/// Partition `net` across `devices` (pipeline order). Returns `None`
/// when no feasible plan exists — fewer compute layers than boards, or
/// some mandatory cell infeasible on its board.
///
/// Deterministic for a fixed [`ShardConfig::seed`] at any
/// [`ShardConfig::threads`]: cells are explored independently (input
/// order restored by [`parallel_map`]) and the DP scan order is fixed.
pub fn partition(
    net: &Network,
    devices: &[FpgaDevice],
    cfg: &ShardConfig,
    cache: &EvalCache,
) -> Option<ShardPlan> {
    let comp_pos = compute_positions(net);
    let n = comp_pos.len();
    let b_count = devices.len();
    if n == 0 || b_count == 0 || b_count > n {
        return None;
    }

    // Canonical slot per board: boards with identical budgets share DSE
    // cells regardless of position in the cluster.
    let mut distinct: Vec<FpgaDevice> = Vec::new();
    let mut slot: Vec<usize> = Vec::with_capacity(b_count);
    for d in devices {
        match distinct.iter().position(|e| same_device(e, d)) {
            Some(i) => slot.push(i),
            None => {
                distinct.push(d.clone());
                slot.push(distinct.len() - 1);
            }
        }
    }

    // Bytes on the wire at each cut `c` (the tensor entering compute
    // layer c = output of the last full layer of the previous segment).
    let cut_bytes: Vec<f64> = (0..=n)
        .map(|c| {
            if c == 0 || c == n {
                0.0
            } else {
                let p = boundary(net, &comp_pos, c);
                tensor_bytes(&net.layers[p - 1].output, cfg.dw)
            }
        })
        .collect();

    // Every (device-slot, range) cell any DP transition can touch, in a
    // fixed order; explored concurrently below (work-stealing absorbs
    // the skew between a 2-layer tail cell and a 10-layer prefix cell).
    let mut wanted: BTreeSet<(usize, usize, usize)> = BTreeSet::new();
    for (b, &s) in slot.iter().enumerate() {
        let i_max = n - (b_count - 1 - b);
        for j in b..i_max {
            let i_lo = (j + 1).max(b + 1);
            for i in i_lo..=i_max {
                if b == 0 && j != 0 {
                    continue; // board 0 always starts at layer 0
                }
                if b == b_count - 1 && i != n {
                    continue; // the last board always ends at layer n
                }
                wanted.insert((s, j, i));
            }
        }
    }
    let tasks: Vec<(usize, usize, usize)> = wanted.into_iter().collect();
    let results = parallel_map(&tasks, cfg.threads, |&(s, j, i)| {
        let sub = subnetwork(net, j, i);
        let ex = cfg.explorer_for(&distinct[s]);
        engine::explore_shared(&sub, &ex, cache)
    });
    let mut evals: HashMap<(usize, usize, usize), Option<engine::ExplorerResult>> =
        HashMap::with_capacity(tasks.len());
    for (k, r) in tasks.into_iter().zip(results) {
        evals.insert(k, r);
    }
    let cell_of = |b: usize, j: usize, i: usize| -> Option<&Candidate> {
        evals.get(&(slot[b], j, i)).and_then(|o| o.as_ref()).map(|r| &r.best)
    };

    // `better` under the configured objective: primary criterion strict,
    // secondary as tie-break; scan order (ascending j) settles the rest
    // deterministically.
    let improves = |cand: (f64, f64), best: Option<(f64, f64)>| -> bool {
        let Some((bf, bl)) = best else { return true };
        match cfg.objective {
            Objective::Throughput => cand.0 > bf || (cand.0 == bf && cand.1 < bl),
            Objective::Latency => cand.1 < bl || (cand.1 == bl && cand.0 > bf),
        }
    };

    // dp[b][i]: best plan putting compute layers [0, i) on boards 0..=b.
    let mut dp: Vec<Vec<Option<Cell>>> = vec![vec![None; n + 1]; b_count];
    let i_max0 = n - (b_count - 1);
    for i in 1..=i_max0 {
        if let Some(c) = cell_of(0, 0, i) {
            dp[0][i] = Some(Cell {
                fps: c.throughput_fps,
                latency_s: c.frame_latency_s,
                prev_j: 0,
            });
        }
    }
    for b in 1..b_count {
        let i_max = n - (b_count - 1 - b);
        for i in (b + 1)..=i_max {
            let mut best: Option<Cell> = None;
            for j in b..i {
                if b == b_count - 1 && i != n {
                    break;
                }
                let Some(prev) = dp[b - 1][j] else { continue };
                let Some(stage) = cell_of(b, j, i) else { continue };
                let link_fps = cfg.link.throughput_fps(cut_bytes[j]);
                let hop_s = cfg.link.transfer_s(cut_bytes[j]);
                let fps = prev.fps.min(link_fps).min(stage.throughput_fps);
                let latency_s = prev.latency_s + hop_s + stage.frame_latency_s;
                if improves((fps, latency_s), best.map(|c| (c.fps, c.latency_s))) {
                    best = Some(Cell { fps, latency_s, prev_j: j });
                }
            }
            dp[b][i] = best;
        }
    }

    // Reconstruct the winning cut sequence from dp[B-1][n].
    let final_cell = dp[b_count - 1][n]?;
    let mut bounds = vec![n];
    let mut i = n;
    for b in (0..b_count).rev() {
        let cell = dp[b][i].expect("dp chain broken");
        bounds.push(cell.prev_j);
        i = cell.prev_j;
    }
    bounds.reverse(); // [0, j_1, ..., j_{B-1}, n]
    debug_assert_eq!(bounds[0], 0);
    debug_assert_eq!(bounds.len(), b_count + 1);

    let mut stages = Vec::with_capacity(b_count);
    for b in 0..b_count {
        let (j, i) = (bounds[b], bounds[b + 1]);
        let candidate = cell_of(b, j, i).expect("winning cell vanished").clone();
        let egress_bytes = cut_bytes[i];
        stages.push(ShardStage {
            board: b,
            device: devices[b].clone(),
            layer_range: (j, i),
            candidate,
            egress_bytes,
            egress_fps: cfg.link.throughput_fps(egress_bytes),
        });
    }

    let total_ops: f64 = net
        .layers
        .iter()
        .filter(|l| l.is_compute())
        .map(|l| l.ops() as f64)
        .sum();
    Some(ShardPlan {
        network: net.name.clone(),
        link: cfg.link,
        stages,
        throughput_fps: final_cell.fps,
        gops: final_cell.fps * total_ops / 1e9,
        latency_s: final_cell.latency_s,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::{zoo, Precision, TensorShape};
    use crate::dse::pso::PsoParams;

    fn vgg(h: usize) -> Network {
        zoo::vgg16_conv(TensorShape::new(3, h, h), Precision::Int16)
    }

    fn quick_cfg() -> ShardConfig {
        ShardConfig {
            pso: PsoParams { population: 8, iterations: 5, ..PsoParams::default() },
            ..ShardConfig::default()
        }
    }

    #[test]
    fn subnetwork_slices_cover_and_chain() {
        let net = vgg(64);
        let n = net.compute_layers().len();
        let a = subnetwork(&net, 0, 6);
        let b = subnetwork(&net, 6, n);
        assert_eq!(a.layers.len() + b.layers.len(), net.layers.len());
        assert_eq!(a.compute_layers().len(), 6);
        assert_eq!(b.compute_layers().len(), n - 6);
        // The cut is shape-consistent: b's first input == a's last output.
        assert_eq!(b.layers[0].input, a.layers.last().unwrap().output);
        a.validate_shapes().unwrap();
        b.validate_shapes().unwrap();
    }

    #[test]
    fn partition_two_boards_covers_all_layers() {
        let net = vgg(64);
        let devices = vec![FpgaDevice::zcu102(), FpgaDevice::zcu102()];
        let cache = EvalCache::new();
        let plan = partition(&net, &devices, &quick_cfg(), &cache).expect("feasible");
        assert_eq!(plan.stages.len(), 2);
        assert_eq!(plan.stages[0].layer_range.0, 0);
        assert_eq!(plan.stages[1].layer_range.1, net.compute_layers().len());
        assert_eq!(plan.stages[0].layer_range.1, plan.stages[1].layer_range.0);
        assert!(plan.throughput_fps > 0.0 && plan.gops > 0.0);
        assert!(plan.latency_s > 0.0);
        assert!(plan.stages[0].egress_bytes > 0.0);
        assert_eq!(plan.stages[1].egress_bytes, 0.0);
        assert!(plan.render().contains("e2e"));
    }

    #[test]
    fn more_boards_than_layers_is_none() {
        let net = vgg(64);
        let n = net.compute_layers().len();
        let devices = vec![FpgaDevice::zcu102(); n + 1];
        let cache = EvalCache::new();
        assert!(partition(&net, &devices, &quick_cfg(), &cache).is_none());
    }

    #[test]
    fn partition_is_thread_invariant() {
        let net = vgg(64);
        let devices = vec![FpgaDevice::zcu102(), FpgaDevice::zc706()];
        let mut c1 = quick_cfg();
        c1.threads = 1;
        let mut c8 = quick_cfg();
        c8.threads = 8;
        let a = partition(&net, &devices, &c1, &EvalCache::new()).expect("t1");
        let b = partition(&net, &devices, &c8, &EvalCache::new()).expect("t8");
        assert_eq!(a.throughput_fps.to_bits(), b.throughput_fps.to_bits());
        assert_eq!(a.latency_s.to_bits(), b.latency_s.to_bits());
        for (x, y) in a.stages.iter().zip(&b.stages) {
            assert_eq!(x.layer_range, y.layer_range);
            assert_eq!(x.candidate.rav, y.candidate.rav);
        }
    }

    #[test]
    fn narrow_link_becomes_the_bottleneck() {
        let net = vgg(64);
        let devices = vec![FpgaDevice::ku115(), FpgaDevice::ku115()];
        let mut cfg = quick_cfg();
        // A pathological 1 MB/s link: serialization dominates any cut.
        cfg.link = LinkModel::new(0.001, 1e-6);
        let cache = EvalCache::new();
        let plan = partition(&net, &devices, &cfg, &cache).expect("feasible");
        assert!(plan.bottleneck().starts_with("link"), "{}", plan.bottleneck());
        // And the fast-link plan is strictly faster end-to-end.
        let fast = partition(&net, &devices, &quick_cfg(), &cache).expect("feasible");
        assert!(fast.throughput_fps > plan.throughput_fps);
    }
}
