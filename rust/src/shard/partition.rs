//! Cut-point planner: partition a network into contiguous pipeline
//! stages — optionally replicated across identical boards — with a
//! dynamic program over `(layer range, device, replication)` cells.
//!
//! A stage covers compute layers `[j, i)` (plus the non-compute layers
//! trailing them) and occupies a contiguous run of `r` identical boards
//! of the cluster; frames are issued round-robin across the replicas, so
//! the stage's effective rate is `r × fps` while the cut to the next
//! stage runs over `min(r, r_next)` parallel links (see
//! [`crate::perfmodel::interleave`]). Every cell's sub-network is
//! explored with the full single-FPGA DSE, so each board gets its own
//! RAV; replicas of a stage run the *same* explored design, so the
//! replication dimension costs no extra DSE. The DP maximizes
//! end-to-end throughput with latency as the tie-breaker; under
//! [`Objective::Latency`] the two criteria swap.
//!
//! ## Search strategies
//!
//! The planner runs in one of two [`PlannerMode`]s over a single shared
//! DP core (identical scan order, tie-breaks, and arithmetic, so the
//! two modes produce bit-identical plans whenever the Pareto beam cap
//! does not bind — pinned by proptest):
//!
//! * [`PlannerMode::Exhaustive`] — pre-enumerate and evaluate every
//!   structurally reachable DSE cell, then fill the DP. This is the
//!   historical planner, kept as the reference implementation and the
//!   bench baseline.
//! * [`PlannerMode::BranchAndBound`] (default) — per DP row, collect
//!   only the cells touched by transitions whose admissible upper bound
//!   (see [`crate::shard::bound`]) can still beat the incumbent plan,
//!   evaluate them in one [`parallel_map`] wave, and skip everything
//!   else. The incumbent is seeded by exactly evaluating the argmax
//!   path of the roof DP. Pruning is *strict* (`bound < incumbent`), so
//!   exact ties — which the scan order resolves first-seen — survive
//!   and the winner is unchanged.
//!
//! [`Planner`] holds the cross-call cell memo: sweeping board-count
//! prefixes through one `Planner` (see
//! [`crate::dse::multi::compare_board_counts`]) re-explores nothing a
//! smaller prefix already evaluated — the k-board DP's expensive
//! content is a sub-table of the (k+1)-board DP's.
//!
//! With [`ShardConfig::max_replicas`] `= 1` the planner reduces
//! bit-exactly to the classic contiguous cut-point DP (one stage per
//! board): the DP scan order, tie-breaks, and arithmetic are identical
//! (multiplying a rate by `1.0` is exact).
//!
//! ## Topology pricing
//!
//! Every transition is priced through the configured
//! [`crate::topo::Topology`]: the cut ceiling and hop cost come from
//! [`Topology::cut_throughput_fps`] / [`Topology::cut_transfer_s`] at
//! the two replica groups' board slots (stage order maps to slots). On
//! a switch fabric the steady state is additionally capped by
//! `bisection / Σ cut_bytes` — a term that couples *all* cuts, so each
//! DP cell keeps a small Pareto frontier over `(throughput-so-far,
//! accumulated cut bytes, latency)` instead of a single winner; two
//! partial plans are incomparable when one is faster so far but has
//! pushed more traffic into the shared switch. On fabrics without a
//! shared ceiling (`p2p`/`ring`/`mesh`) the frontier degenerates to one
//! entry chosen by exactly the old predicate, keeping the planner
//! bit-identical to the uniform-link DP (pinned by proptest).
//!
//! Frontiers live in a flat arena ([`Arena`]): one contiguous entry
//! vector plus a `(board, layers, r) → span` index, committed row by
//! row — no per-cell `Vec` churn. When the beam cap
//! ([`ShardConfig::fabric_frontier_cap`]) fires, the drop count is
//! surfaced in [`PlanStats::frontier_dropped`] rather than silently
//! truncating the search.

use std::collections::{BTreeSet, HashMap};
use std::sync::Arc;

use crate::dnn::Network;
use crate::dse::cache::EvalCache;
use crate::dse::engine::{self, Candidate, Objective};
use crate::fpga::FpgaDevice;
use crate::perfmodel::interleave::{self, StageRate};
use crate::perfmodel::link::LinkModel;
use crate::shard::bound::{BoundCtx, ADMISSIBILITY_SLACK};
use crate::shard::link::tensor_bytes;
use crate::shard::{PlannerMode, ShardConfig};
use crate::topo::{FabricKind, SlotRun, Topology};
use crate::util::parallel::parallel_map;

/// One stage of a [`ShardPlan`]: a layer range on a replica group.
#[derive(Debug, Clone)]
pub struct ShardStage {
    /// Stage index in pipeline order.
    pub stage: usize,
    /// Cluster board indices running this stage's replicas: a
    /// contiguous ascending run of identical boards (len >= 1 is the
    /// replication factor; frames interleave round-robin across them).
    pub boards: Vec<usize>,
    pub device: FpgaDevice,
    /// Compute-layer range `[start, end)` this stage runs (indices into
    /// the network's compute layers, in order).
    pub layer_range: (usize, usize),
    /// The explored single-FPGA design every replica of this stage runs.
    pub candidate: Candidate,
    /// Effective stage rate: `replicas × candidate fps`.
    pub stage_fps: f64,
    /// Activation bytes leaving this stage toward the next stage per
    /// frame (0 for the last stage).
    pub egress_bytes: f64,
    /// Steady-state ceiling of the egress cut over its
    /// `min(r, r_next)` parallel links (∞ for the last stage).
    pub egress_fps: f64,
}

impl ShardStage {
    /// Replication factor of this stage.
    pub fn replicas(&self) -> usize {
        self.boards.len()
    }
}

/// Search accounting of one planner call — how much work the DP did and
/// how much the bounds saved, plus whether the beam cap made the search
/// inexact (the no-silent-caps counter).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PlanStats {
    /// DSE cell explorations actually run during this call.
    pub cells_evaluated: u64,
    /// Cells served from the [`Planner`] memo (a previous call of the
    /// same planner — e.g. a smaller board-count prefix — explored
    /// them).
    pub cells_reused: u64,
    /// Distinct cells the exhaustive planner would have evaluated that
    /// branch-and-bound proved could not beat the incumbent.
    pub cells_pruned: u64,
    /// DP transitions skipped by the admissible bound test.
    pub transitions_pruned: u64,
    /// Pareto-frontier entries dropped by the beam cap
    /// ([`ShardConfig::fabric_frontier_cap`]). Non-zero means the
    /// search was a beam, not exact — surfaced in the plan JSON and the
    /// report table per the no-silent-caps rule.
    pub frontier_dropped: u64,
    /// Score of the branch-and-bound incumbent seed (0 when pruning was
    /// off or no seed was feasible).
    pub incumbent_fps: f64,
}

impl PlanStats {
    /// True when no beam pruning occurred — the DP searched the full
    /// Pareto frontier and the plan is the exact optimum of its space.
    pub fn is_exact(&self) -> bool {
        self.frontier_dropped == 0
    }

    /// Fold another call's counters into this one (incumbent keeps the
    /// max — it is a gauge, not a counter).
    pub fn absorb(&mut self, o: &PlanStats) {
        self.cells_evaluated += o.cells_evaluated;
        self.cells_reused += o.cells_reused;
        self.cells_pruned += o.cells_pruned;
        self.transitions_pruned += o.transitions_pruned;
        self.frontier_dropped += o.frontier_dropped;
        self.incumbent_fps = self.incumbent_fps.max(o.incumbent_fps);
    }
}

/// A full multi-board partition: stages in pipeline order plus the
/// system-level model outputs.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    pub network: String,
    /// Per-port link of the interconnect (see [`ShardPlan::fabric`]).
    pub link: LinkModel,
    /// The wiring pattern the plan was priced against.
    pub fabric: FabricKind,
    pub stages: Vec<ShardStage>,
    /// End-to-end steady-state frames/s:
    /// `min(min_s r_s·fps_s, min_cut min(r_s, r_s+1)·link_fps_cut)`.
    pub throughput_fps: f64,
    /// Whole-network sustained GOP/s at that frame rate.
    pub gops: f64,
    /// Single-frame latency: stage latencies plus hop costs, seconds
    /// (replication-invariant: a frame visits one replica per stage).
    pub latency_s: f64,
    /// Search accounting of the planner call that produced this plan.
    pub stats: PlanStats,
}

impl ShardPlan {
    /// Total boards occupied by the plan (Σ replicas).
    pub fn board_count(&self) -> usize {
        self.stages.iter().map(|s| s.replicas()).sum()
    }

    /// Largest replication factor of any stage (1 = pure contiguous).
    pub fn max_replication(&self) -> usize {
        self.stages.iter().map(|s| s.replicas()).max().unwrap_or(1)
    }

    /// The per-stage rates/latencies as the analytic interleave model
    /// sees them (the differential suite's entry point).
    pub fn stage_rates(&self) -> Vec<StageRate> {
        self.stages
            .iter()
            .map(|s| {
                StageRate::new(
                    s.replicas(),
                    s.candidate.throughput_fps,
                    s.candidate.frame_latency_s,
                )
            })
            .collect()
    }

    /// Bytes on the wire at each internal cut, in pipeline order
    /// (`stages.len() - 1` entries).
    pub fn cut_bytes(&self) -> Vec<f64> {
        self.stages
            .iter()
            .take(self.stages.len().saturating_sub(1))
            .map(|s| s.egress_bytes)
            .collect()
    }

    /// The interconnect this plan was priced against.
    pub fn topo(&self) -> Topology {
        Topology::new(self.link, self.fabric)
    }

    /// Where each stage's replica group sits in the cluster, in stage
    /// order (the topology resolution input).
    pub fn slot_runs(&self) -> Vec<SlotRun> {
        self.stages
            .iter()
            .map(|s| SlotRun::new(s.boards[0], s.boards.len()))
            .collect()
    }

    /// The shared-fabric ceiling over this plan's total cut traffic
    /// (`∞` off switch fabrics or with no cut bytes).
    pub fn fabric_fps(&self) -> f64 {
        self.topo().fabric_fps(self.cut_bytes().iter().sum())
    }

    /// Re-price this plan's structure (same cuts, replicas, and
    /// per-board designs) on a different fabric over the same per-port
    /// link — what a topology-*blind* plan actually delivers when
    /// deployed on a switch or ring. Stage rates are unchanged; cut
    /// ceilings, the fabric term, and hop latencies are re-resolved.
    pub fn repriced_on(&self, fabric: FabricKind) -> ShardPlan {
        let topo = Topology::new(self.link, fabric);
        let rates = self.stage_rates();
        let slots = self.slot_runs();
        let cuts = self.cut_bytes();
        let mut stages = self.stages.clone();
        for (s_idx, s) in stages.iter_mut().enumerate() {
            let cur = slots[s_idx];
            let next = slots
                .get(s_idx + 1)
                .copied()
                .unwrap_or_else(|| SlotRun::new(cur.first + cur.len, 1));
            s.egress_fps = topo.cut_throughput_fps(s.egress_bytes, cur, next);
        }
        let throughput_fps = interleave::steady_state_fps_on(&topo, &rates, &slots, &cuts);
        // Scale GOP/s with the new rate; an identity repricing (same
        // fabric, same ceilings) keeps the stored value bit-for-bit.
        let gops = if throughput_fps.to_bits() == self.throughput_fps.to_bits() {
            self.gops
        } else if self.throughput_fps > 0.0 {
            throughput_fps * (self.gops / self.throughput_fps)
        } else {
            0.0
        };
        ShardPlan {
            network: self.network.clone(),
            link: self.link,
            fabric,
            stages,
            throughput_fps,
            gops,
            latency_s: interleave::frame_latency_s_on(&topo, &rates, &slots, &cuts),
            stats: self.stats.clone(),
        }
    }

    /// What limits the plan: `stage<i>`, `link<i>-><i+1>`, or the
    /// shared switch (`fabric`).
    pub fn bottleneck(&self) -> String {
        let eps = self.throughput_fps * 1e-9;
        for s in &self.stages {
            if s.stage_fps <= self.throughput_fps + eps {
                return format!("stage{}", s.stage);
            }
            if s.egress_fps <= self.throughput_fps + eps {
                return format!("link{}->{}", s.stage, s.stage + 1);
            }
        }
        if self.fabric_fps() <= self.throughput_fps + eps {
            return "fabric".into();
        }
        "none".into()
    }

    /// Aligned text rendering (CLI output).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{}: {} stages on {} boards over {} link, {} fabric\n",
            self.network,
            self.stages.len(),
            self.board_count(),
            self.link,
            self.fabric
        ));
        out.push_str(&format!(
            "{:<6} {:<8} {:<8} {:<10} {:<26} {:>9} {:>9} {:>7} {:>7} {:>10}\n",
            "stage", "boards", "device", "layers", "RAV", "fps", "GOP/s", "DSP", "BRAM", "egress"
        ));
        for s in &self.stages {
            let egress = if s.egress_bytes > 0.0 {
                format!("{:.0} KB", s.egress_bytes / 1024.0)
            } else {
                "-".into()
            };
            let boards = if s.replicas() == 1 {
                format!("{}", s.boards[0])
            } else {
                format!("{}-{}x{}", s.boards[0], s.boards[s.boards.len() - 1], s.replicas())
            };
            out.push_str(&format!(
                "{:<6} {:<8} {:<8} {:<10} {:<26} {:>9.1} {:>9.1} {:>7.0} {:>7.0} {:>10}\n",
                s.stage,
                boards,
                s.device.name,
                format!("{}..{}", s.layer_range.0, s.layer_range.1),
                format!("{}", s.candidate.rav),
                s.stage_fps,
                s.candidate.gops * s.replicas() as f64,
                s.candidate.dsp_used,
                s.candidate.bram_used,
                egress,
            ));
        }
        out.push_str(&format!(
            "e2e: {:.1} img/s = {:.1} GOP/s, latency {:.2} ms, bottleneck {}\n",
            self.throughput_fps,
            self.gops,
            self.latency_s * 1e3,
            self.bottleneck()
        ));
        out.push_str(&format!(
            "search: {} cells explored, {} reused, {} pruned; {}\n",
            self.stats.cells_evaluated,
            self.stats.cells_reused,
            self.stats.cells_pruned,
            if self.stats.is_exact() {
                "exact".to_string()
            } else {
                format!("beam ({} frontier entries dropped)", self.stats.frontier_dropped)
            }
        ));
        out
    }
}

/// Positions of the compute layers within `net.layers`.
fn compute_positions(net: &Network) -> Vec<usize> {
    net.layers
        .iter()
        .enumerate()
        .filter(|(_, l)| l.is_compute())
        .map(|(i, _)| i)
        .collect()
}

/// Full-layer boundary of compute-layer index `c`: non-compute layers
/// trail the compute layer they follow (a pool stays with its conv).
fn boundary(net: &Network, comp_pos: &[usize], c: usize) -> usize {
    if c == 0 {
        0
    } else if c == comp_pos.len() {
        net.layers.len()
    } else {
        comp_pos[c]
    }
}

/// The sub-network covering compute layers `[c_start, c_end)` of `net`,
/// including the non-compute layers trailing each of them.
pub fn subnetwork(net: &Network, c_start: usize, c_end: usize) -> Network {
    let comp_pos = compute_positions(net);
    assert!(c_start < c_end && c_end <= comp_pos.len(), "bad range {c_start}..{c_end}");
    let lo = boundary(net, &comp_pos, c_start);
    let hi = boundary(net, &comp_pos, c_end);
    let layers = net.layers[lo..hi].to_vec();
    Network {
        name: format!("{}[{}..{}]", net.name, c_start, c_end),
        input: layers[0].input,
        layers,
    }
}

/// Two catalogue devices with identical budgets are the same board type
/// (the planner reuses their DSE cells, and a replica group may span
/// them).
fn same_device(a: &FpgaDevice, b: &FpgaDevice) -> bool {
    a.dsp == b.dsp
        && a.bram18k == b.bram18k
        && a.bandwidth_gbps == b.bandwidth_gbps
        && a.freq_mhz == b.freq_mhz
}

#[derive(Clone, Copy)]
struct Cell {
    fps: f64,
    latency_s: f64,
    /// Total activation bytes this partial plan pushes across cuts per
    /// frame — the shared-fabric demand accumulated so far (priced at
    /// the end as `bisection / cut_sum` on switch fabrics).
    cut_sum: f64,
    /// Start compute-layer index of the last stage in this cell's plan.
    start_j: usize,
    /// Replication factor of the *previous* stage (0 for the first).
    prev_r: usize,
    /// Index into the previous cell's frontier (0 off switch fabrics,
    /// where frontiers hold a single entry).
    prev_idx: usize,
}

/// One committed frontier's location in the [`Arena`], plus its max
/// throughput (the row-level value branch-and-bound tests against).
#[derive(Clone, Copy)]
struct Span {
    start: u32,
    len: u32,
    max_fps: f64,
}

/// Flat-arena DP table: all frontier entries live in one contiguous
/// vector; `(board, layers-done, replicas) → Span` indexes into it.
/// Rows are committed exactly once, in scan order, so spans never move
/// — replacing the historical `Vec<Vec<Vec<Vec<Cell>>>>` and its
/// per-cell allocation churn.
struct Arena {
    entries: Vec<Cell>,
    spans: Vec<Span>,
    n: usize,
    maxr: usize,
}

impl Arena {
    fn new(b_count: usize, n: usize, maxr: usize) -> Self {
        Arena {
            entries: Vec::new(),
            spans: vec![
                Span { start: 0, len: 0, max_fps: f64::NEG_INFINITY };
                b_count * (n + 1) * (maxr + 1)
            ],
            n,
            maxr,
        }
    }

    fn idx(&self, b: usize, i: usize, r: usize) -> usize {
        (b * (self.n + 1) + i) * (self.maxr + 1) + r
    }

    fn row(&self, b: usize, i: usize, r: usize) -> &[Cell] {
        let s = self.spans[self.idx(b, i, r)];
        &self.entries[s.start as usize..(s.start + s.len) as usize]
    }

    fn max_fps(&self, b: usize, i: usize, r: usize) -> f64 {
        self.spans[self.idx(b, i, r)].max_fps
    }

    /// Append `scratch` as the frontier of `(b, i, r)` (drains it,
    /// keeping its capacity for the next row).
    fn commit(&mut self, b: usize, i: usize, r: usize, scratch: &mut Vec<Cell>) {
        let start = self.entries.len() as u32;
        let mut max_fps = f64::NEG_INFINITY;
        for c in scratch.iter() {
            max_fps = max_fps.max(c.fps);
        }
        let idx = self.idx(b, i, r);
        self.spans[idx] = Span { start, len: scratch.len() as u32, max_fps };
        self.entries.append(scratch);
    }
}

/// `better` under the configured objective: primary criterion strict,
/// secondary as tie-break; scan order settles the rest deterministically
/// (first candidate wins ties).
fn improves(objective: Objective, cand: (f64, f64), best: Option<(f64, f64)>) -> bool {
    let Some((bf, bl)) = best else { return true };
    match objective {
        Objective::Throughput => cand.0 > bf || (cand.0 == bf && cand.1 < bl),
        Objective::Latency => cand.1 < bl || (cand.1 == bl && cand.0 > bf),
    }
}

/// Admit a candidate into a cell's frontier. Off switch fabrics the
/// frontier holds one entry picked by [`improves`] — bit-identical to
/// the single-cell DP. On a switch, accumulated cut bytes decide the
/// final fabric term, so Pareto-incomparable entries (faster-so-far
/// vs less switch traffic vs lower latency) must coexist. Every entry
/// dropped by the beam cap is counted into `dropped` — truncation is
/// never silent.
#[allow(clippy::too_many_arguments)]
fn admit(
    front: &mut Vec<Cell>,
    cand: Cell,
    fabric: bool,
    cap: usize,
    topo: &Topology,
    objective: Objective,
    dropped: &mut u64,
) {
    if !fabric {
        if improves(
            objective,
            (cand.fps, cand.latency_s),
            front.first().map(|c| (c.fps, c.latency_s)),
        ) {
            front.clear();
            front.push(cand);
        }
        return;
    }
    for c in front.iter() {
        if c.fps >= cand.fps && c.latency_s <= cand.latency_s && c.cut_sum <= cand.cut_sum {
            return; // dominated (equal on all axes keeps the first seen)
        }
    }
    front.retain(|c| {
        !(cand.fps >= c.fps && cand.latency_s <= c.latency_s && cand.cut_sum <= c.cut_sum)
    });
    front.push(cand);
    if front.len() > cap {
        // Deterministic beam prune: drop the worst fabric-priced
        // entry (ties: higher latency, then more switch traffic).
        let worst = front
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| {
                let sa = a.fps.min(topo.fabric_fps(a.cut_sum));
                let sb = b.fps.min(topo.fabric_fps(b.cut_sum));
                sa.partial_cmp(&sb)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(
                        b.latency_s
                            .partial_cmp(&a.latency_s)
                            .unwrap_or(std::cmp::Ordering::Equal),
                    )
                    .then(
                        b.cut_sum
                            .partial_cmp(&a.cut_sum)
                            .unwrap_or(std::cmp::Ordering::Equal),
                    )
            })
            .map(|(i, _)| i)
            .unwrap_or(0);
        front.swap_remove(worst);
        *dropped += 1;
    }
}

/// DSE cell key: `(device slot, start layer, end layer)`.
type CellKey = (usize, usize, usize);

/// Reusable cut-point planner over one `(network, cluster, config)`
/// instance. [`Planner::plan`] partitions a board-count *prefix* of the
/// cluster; the expensive per-cell DSE results are memoized across
/// calls, so a 1/2/4/../N board sweep (see
/// [`crate::dse::multi::compare_board_counts`]) evaluates every cell at
/// most once — the incremental-prefix reuse half of the planner's
/// speedup, next to branch-and-bound pruning.
pub struct Planner<'a> {
    net: &'a Network,
    devices: &'a [FpgaDevice],
    cfg: &'a ShardConfig,
    cache: &'a EvalCache,
    /// Compute-layer count of `net`.
    n: usize,
    /// Distinct device catalogue (canonicalized by [`same_device`]).
    distinct: Vec<FpgaDevice>,
    /// Canonical slot per cluster board (full cluster; prefixes slice).
    slot: Vec<usize>,
    /// Same-device run length ending at each board (prefix-safe: entry
    /// `b` only depends on boards `0..=b`).
    run_len: Vec<usize>,
    /// Bytes on the wire at each cut (`n + 1` entries).
    cut_bytes: Vec<f64>,
    /// Prefix sums of compute-layer ops (`n + 1` entries).
    ops_pfx: Vec<f64>,
    /// Per-slot slack-padded `peak_gops · 1e9` roof numerator.
    peak_fps_num: Vec<f64>,
    /// Cross-call DSE cell memo: `None` = explored and infeasible.
    memo: HashMap<CellKey, Option<Arc<Candidate>>>,
    /// Counters accumulated over every [`Planner::plan`] call.
    total: PlanStats,
}

impl<'a> Planner<'a> {
    pub fn new(
        net: &'a Network,
        devices: &'a [FpgaDevice],
        cfg: &'a ShardConfig,
        cache: &'a EvalCache,
    ) -> Self {
        let comp_pos = compute_positions(net);
        let n = comp_pos.len();
        // Canonical slot per board: boards with identical budgets share
        // DSE cells regardless of position in the cluster.
        let mut distinct: Vec<FpgaDevice> = Vec::new();
        let mut slot: Vec<usize> = Vec::with_capacity(devices.len());
        for d in devices {
            match distinct.iter().position(|e| same_device(e, d)) {
                Some(i) => slot.push(i),
                None => {
                    distinct.push(d.clone());
                    slot.push(distinct.len() - 1);
                }
            }
        }
        // run_len[b]: length of the same-device run ending at board b —
        // the widest replica group that may end there.
        let mut run_len = vec![1usize; devices.len()];
        for b in 1..devices.len() {
            if slot[b] == slot[b - 1] {
                run_len[b] = run_len[b - 1] + 1;
            }
        }
        // Bytes on the wire at each cut `c` (the tensor entering
        // compute layer c = output of the last full layer of the
        // previous segment).
        let cut_bytes: Vec<f64> = (0..=n)
            .map(|c| {
                if c == 0 || c == n {
                    0.0
                } else {
                    let p = boundary(net, &comp_pos, c);
                    tensor_bytes(&net.layers[p - 1].output, cfg.dw)
                }
            })
            .collect();
        // ops_pfx[i] = Σ ops of compute layers [0, i) — the same
        // compute-only accounting `engine::evaluate` uses for `gops`,
        // so the roof bound divides by exactly the right denominator.
        let mut ops_pfx = Vec::with_capacity(n + 1);
        ops_pfx.push(0.0);
        for l in net.layers.iter().filter(|l| l.is_compute()) {
            ops_pfx.push(ops_pfx.last().copied().unwrap_or(0.0) + l.ops() as f64);
        }
        let peak_fps_num: Vec<f64> = distinct
            .iter()
            .map(|d| ADMISSIBILITY_SLACK * d.peak_gops(cfg.ww.alpha()) * 1e9)
            .collect();
        Planner {
            net,
            devices,
            cfg,
            cache,
            n,
            distinct,
            slot,
            run_len,
            cut_bytes,
            ops_pfx,
            peak_fps_num,
            memo: HashMap::new(),
            total: PlanStats::default(),
        }
    }

    /// Counters accumulated across every `plan` call of this planner.
    pub fn total_stats(&self) -> &PlanStats {
        &self.total
    }

    /// Distinct DSE cells explored so far (across all calls).
    pub fn memo_len(&self) -> usize {
        self.memo.len()
    }

    /// Explored design of cell `(slot-of-b, j, i)`, if feasible and
    /// already evaluated.
    fn cell(&self, b: usize, j: usize, i: usize) -> Option<&Arc<Candidate>> {
        self.memo.get(&(self.slot[b], j, i)).and_then(|o| o.as_ref())
    }

    /// Admissible per-replica fps roof of cell `(s, j, i)` — must match
    /// [`BoundCtx::cell_fps_ub`] exactly (same expression) so pass A
    /// and pass B of the pruned DP agree on every decision.
    fn cell_fps_ub(&self, s: usize, j: usize, i: usize) -> f64 {
        let ops = self.ops_pfx[i] - self.ops_pfx[j];
        if ops > 0.0 {
            self.peak_fps_num[s] / ops
        } else {
            f64::INFINITY
        }
    }

    /// Evaluate every not-yet-memoized cell of `need` in one
    /// work-stealing wave. `seen` de-duplicates per-call accounting
    /// (the same cell can be needed by several boards of one call).
    fn eval_wave(
        &mut self,
        need: &BTreeSet<CellKey>,
        seen: &mut BTreeSet<CellKey>,
        stats: &mut PlanStats,
    ) {
        let mut tasks: Vec<CellKey> = Vec::new();
        for &k in need {
            if !seen.insert(k) {
                continue; // accounted earlier in this call
            }
            if self.memo.contains_key(&k) {
                stats.cells_reused += 1;
            } else {
                tasks.push(k);
            }
        }
        if tasks.is_empty() {
            return;
        }
        let (net, cache, cfg) = (self.net, self.cache, self.cfg);
        let distinct = &self.distinct;
        let results = parallel_map(&tasks, cfg.threads, |&(s, j, i)| {
            let sub = subnetwork(net, j, i);
            let ex = cfg.explorer_for(&distinct[s]);
            engine::explore_shared(&sub, &ex, cache)
        });
        stats.cells_evaluated += tasks.len() as u64;
        for (k, r) in tasks.into_iter().zip(results) {
            self.memo.insert(k, r.map(|res| Arc::new(res.best)));
        }
    }

    /// Exactly price the chained plan described by `path` (stages as
    /// `(j, i, b_end, r)` in pipeline order) with the *same arithmetic
    /// and operation order* as the DP — so the resulting score is a
    /// value the DP itself can reach, making it a sound (never
    /// over-tight) pruning incumbent. `None` when any cell of the path
    /// is DSE-infeasible.
    fn price_path(&self, path: &[(usize, usize, usize, usize)], topo: &Topology) -> Option<f64> {
        let mut fps = 0.0f64;
        let mut cut_sum = 0.0f64;
        for (s_idx, &(j, i, b_end, r)) in path.iter().enumerate() {
            let cand = self.cell(b_end, j, i)?;
            let eff = r as f64 * cand.throughput_fps;
            if s_idx == 0 {
                fps = eff;
            } else {
                let (_pj, _pi, pb_end, pr) = path[s_idx - 1];
                let prev_run = SlotRun::new(pb_end + 1 - pr, pr);
                let cur_run = SlotRun::new(b_end + 1 - r, r);
                let link = topo.cut_throughput_fps(self.cut_bytes[j], prev_run, cur_run);
                fps = fps.min(link).min(eff);
                cut_sum += self.cut_bytes[j];
            }
        }
        Some(fps.min(topo.fabric_fps(cut_sum)))
    }

    /// Partition `net` across the first `b_count` boards of the
    /// cluster. See [`partition`] for the contract; this entry point
    /// additionally reuses the cell memo across calls.
    pub fn plan(&mut self, b_count: usize) -> Option<ShardPlan> {
        assert!(b_count <= self.devices.len(), "prefix larger than cluster");
        let n = self.n;
        let maxr = self.cfg.max_replicas.max(1).min(b_count.max(1));
        // Minimum stages needed to cover `boards` boards at <= maxr each.
        let min_stages = move |boards: usize| boards.div_ceil(maxr);
        if n == 0 || b_count == 0 || min_stages(b_count) > n {
            return None;
        }
        let cfg = self.cfg;
        let topo = cfg.topology();
        let fabric = topo.has_fabric();
        let cap = cfg.fabric_frontier_cap.max(1);
        let lazy = cfg.planner == PlannerMode::BranchAndBound;
        let mut stats = PlanStats::default();
        let mut seen: BTreeSet<CellKey> = BTreeSet::new();

        // Branch-and-bound preamble: suffix roof table + incumbent seed
        // (the roof DP's argmax path, evaluated exactly). Pruning only
        // under the throughput objective — the bounds bound throughput.
        let (incumbent, suffix) = if lazy && cfg.objective == Objective::Throughput {
            let (path, suf) = {
                let bc = BoundCtx {
                    k: b_count,
                    n,
                    maxr,
                    slot: &self.slot[..b_count],
                    run_len: &self.run_len[..b_count],
                    ops_pfx: &self.ops_pfx,
                    peak_fps_num: &self.peak_fps_num,
                    cut_bytes: &self.cut_bytes,
                    topo: &topo,
                };
                (bc.forward_path(), bc.suffix())
            };
            let inc = path.and_then(|path| {
                let mut need: BTreeSet<CellKey> = BTreeSet::new();
                for &(j, i, b_end, _r) in &path {
                    need.insert((self.slot[b_end], j, i));
                }
                self.eval_wave(&need, &mut seen, &mut stats);
                self.price_path(&path, &topo)
            });
            if let Some(s) = inc {
                stats.incumbent_fps = s;
            }
            (inc, Some(suf))
        } else {
            (None, None)
        };
        let suf_get =
            |b: usize, i: usize, r: usize| suffix.as_ref().map_or(f64::INFINITY, |t| t.get(b, i, r));

        // Exhaustive mode: the historical eager pre-enumeration — every
        // structurally reachable cell, evaluated in one wave up front.
        if !lazy {
            let mut wanted: BTreeSet<CellKey> = BTreeSet::new();
            for b in 0..b_count {
                let rmax = maxr.min(self.run_len[b]).min(b + 1);
                for r in 1..=rmax {
                    let before = b + 1 - r;
                    let after = b_count - 1 - b;
                    if min_stages(after) >= n {
                        continue;
                    }
                    let i_max = n - min_stages(after);
                    let j_lo = min_stages(before);
                    for j in j_lo..i_max {
                        if before == 0 && j != 0 {
                            break; // the first stage always starts at layer 0
                        }
                        if b == b_count - 1 {
                            // The last stage always ends at layer n.
                            if n > j {
                                wanted.insert((self.slot[b], j, n));
                            }
                        } else {
                            for i in (j + 1)..=i_max {
                                wanted.insert((self.slot[b], j, i));
                            }
                        }
                    }
                }
            }
            self.eval_wave(&wanted, &mut seen, &mut stats);
        }

        // The DP proper. dp(b, i, r): frontier of plans putting compute
        // layers [0, i) on boards 0..=b with the last stage replicated
        // r-wide. One entry off switch fabrics; a Pareto set on them.
        //
        // In lazy mode each board runs two passes over the *same*
        // skeleton: pass A collects the cells surviving the bound test
        // into one evaluation wave; pass B replays the skeleton with
        // exact values. Both passes see identical committed rows, so
        // their pruning decisions agree.
        let mut arena = Arena::new(b_count, n, maxr);
        let mut dropped: u64 = 0;
        let mut scratch: Vec<Cell> = Vec::new();
        let mut pruned_cells: BTreeSet<CellKey> = BTreeSet::new();
        for b in 0..b_count {
            let rmax = maxr.min(self.run_len[b]).min(b + 1);
            let after = b_count - 1 - b;
            if min_stages(after) >= n {
                continue;
            }
            let i_max = n - min_stages(after);

            if lazy {
                let mut need: BTreeSet<CellKey> = BTreeSet::new();
                for i in 1..=i_max {
                    if b == b_count - 1 && i != n {
                        continue;
                    }
                    for r in 1..=rmax {
                        let before = b + 1 - r;
                        if before == 0 {
                            let key = (self.slot[b], 0, i);
                            match incumbent {
                                Some(inc)
                                    if (r as f64 * self.cell_fps_ub(self.slot[b], 0, i))
                                        .min(suf_get(b, i, r))
                                        < inc =>
                                {
                                    stats.transitions_pruned += 1;
                                    pruned_cells.insert(key);
                                }
                                _ => {
                                    need.insert(key);
                                }
                            }
                            continue;
                        }
                        let pb = before - 1;
                        let cur_run = SlotRun::new(before, r);
                        for j in min_stages(before).max(1)..i {
                            let key = (self.slot[b], j, i);
                            let roof = r as f64 * self.cell_fps_ub(self.slot[b], j, i);
                            for r_prev in 1..=maxr.min(self.run_len[pb]).min(pb + 1) {
                                if arena.row(pb, j, r_prev).is_empty() {
                                    continue;
                                }
                                if let Some(inc) = incumbent {
                                    let prev_run = SlotRun::new(before - r_prev, r_prev);
                                    let link_fps = topo.cut_throughput_fps(
                                        self.cut_bytes[j],
                                        prev_run,
                                        cur_run,
                                    );
                                    let ub = arena
                                        .max_fps(pb, j, r_prev)
                                        .min(link_fps)
                                        .min(roof)
                                        .min(suf_get(b, i, r));
                                    if ub < inc {
                                        stats.transitions_pruned += 1;
                                        pruned_cells.insert(key);
                                        continue;
                                    }
                                }
                                need.insert(key);
                            }
                        }
                    }
                }
                self.eval_wave(&need, &mut seen, &mut stats);
            }

            // Pass B: exact transitions, identical skeleton and order.
            for i in 1..=i_max {
                if b == b_count - 1 && i != n {
                    continue;
                }
                for r in 1..=rmax {
                    let before = b + 1 - r;
                    debug_assert!(scratch.is_empty());
                    if before == 0 {
                        // First stage: layers [0, i) on boards 0..=b,
                        // r-wide. Same prune test as pass A.
                        let keep = match incumbent {
                            Some(inc) => {
                                (r as f64 * self.cell_fps_ub(self.slot[b], 0, i))
                                    .min(suf_get(b, i, r))
                                    >= inc
                            }
                            None => true,
                        };
                        if keep {
                            if let Some(c) = self.cell(b, 0, i) {
                                scratch.push(Cell {
                                    fps: r as f64 * c.throughput_fps,
                                    latency_s: c.frame_latency_s,
                                    cut_sum: 0.0,
                                    start_j: 0,
                                    prev_r: 0,
                                    prev_idx: 0,
                                });
                            }
                        }
                        arena.commit(b, i, r, &mut scratch);
                        continue;
                    }
                    let pb = before - 1;
                    let cur_run = SlotRun::new(before, r);
                    for j in min_stages(before).max(1)..i {
                        let Some(stage) = self.cell(b, j, i) else { continue };
                        let eff = r as f64 * stage.throughput_fps;
                        let stage_latency = stage.frame_latency_s;
                        let roof = r as f64 * self.cell_fps_ub(self.slot[b], j, i);
                        for r_prev in 1..=maxr.min(self.run_len[pb]).min(pb + 1) {
                            if arena.row(pb, j, r_prev).is_empty() {
                                continue;
                            }
                            // A non-empty frontier implies r_prev fits
                            // at board pb, so the run start cannot
                            // underflow.
                            let prev_run = SlotRun::new(before - r_prev, r_prev);
                            let link_fps =
                                topo.cut_throughput_fps(self.cut_bytes[j], prev_run, cur_run);
                            if let Some(inc) = incumbent {
                                // Same test as pass A (counted there).
                                let ub = arena
                                    .max_fps(pb, j, r_prev)
                                    .min(link_fps)
                                    .min(roof)
                                    .min(suf_get(b, i, r));
                                if ub < inc {
                                    continue;
                                }
                            }
                            let hop_s =
                                topo.cut_transfer_s(self.cut_bytes[j], prev_run, cur_run);
                            for (pi, prev) in arena.row(pb, j, r_prev).iter().enumerate() {
                                let fps = prev.fps.min(link_fps).min(eff);
                                let latency_s = prev.latency_s + hop_s + stage_latency;
                                admit(
                                    &mut scratch,
                                    Cell {
                                        fps,
                                        latency_s,
                                        cut_sum: prev.cut_sum + self.cut_bytes[j],
                                        start_j: j,
                                        prev_r: r_prev,
                                        prev_idx: pi,
                                    },
                                    fabric,
                                    cap,
                                    &topo,
                                    cfg.objective,
                                    &mut dropped,
                                );
                            }
                        }
                    }
                    // Entries strictly below the incumbent can never win
                    // nor tie on the primary criterion — drop them so
                    // downstream rows stop extending dead branches.
                    if let Some(inc) = incumbent {
                        scratch.retain(|c| c.fps >= inc);
                    }
                    arena.commit(b, i, r, &mut scratch);
                }
            }
        }
        stats.cells_pruned = pruned_cells.difference(&seen).count() as u64;
        stats.frontier_dropped = dropped;

        // Pick the winning final cell — the shared-fabric ceiling is
        // priced here, over each candidate's accumulated cut traffic —
        // then walk the chain back to the front.
        let mut chosen: Option<(usize, usize, f64, f64)> = None; // (r, idx, fps, latency)
        for r in 1..=maxr.min(self.run_len[b_count - 1]).min(b_count) {
            for (idx, c) in arena.row(b_count - 1, n, r).iter().enumerate() {
                let scored = c.fps.min(topo.fabric_fps(c.cut_sum));
                if improves(
                    cfg.objective,
                    (scored, c.latency_s),
                    chosen.map(|(_, _, f, l)| (f, l)),
                ) {
                    chosen = Some((r, idx, scored, c.latency_s));
                }
            }
        }
        self.total.absorb(&stats);
        let (final_r, final_idx, final_fps, final_latency) = chosen?;

        // Reconstruct (start layer, end layer, last board, replicas) per
        // stage, back to front.
        let mut rev: Vec<(usize, usize, usize, usize)> = Vec::new();
        let mut i_cur = n;
        let mut b_cur = b_count - 1;
        let mut r_cur = final_r;
        let mut idx_cur = final_idx;
        loop {
            let cell = arena.row(b_cur, i_cur, r_cur)[idx_cur];
            rev.push((cell.start_j, i_cur, b_cur, r_cur));
            if cell.start_j == 0 {
                debug_assert_eq!(b_cur + 1, r_cur, "first stage must start at board 0");
                break;
            }
            let next_b = b_cur - r_cur;
            i_cur = cell.start_j;
            r_cur = cell.prev_r;
            idx_cur = cell.prev_idx;
            b_cur = next_b;
        }
        rev.reverse();

        let mut stages = Vec::with_capacity(rev.len());
        for (s_idx, &(j, i, b_end, r)) in rev.iter().enumerate() {
            let candidate =
                self.cell(b_end, j, i).expect("winning cell vanished").as_ref().clone();
            let egress_bytes = self.cut_bytes[i];
            let r_next = rev.get(s_idx + 1).map(|&(_, _, _, rn)| rn).unwrap_or(1);
            let stage_fps = r as f64 * candidate.throughput_fps;
            let this_run = SlotRun::new(b_end + 1 - r, r);
            let next_run = SlotRun::new(b_end + 1, r_next);
            stages.push(ShardStage {
                stage: s_idx,
                boards: (b_end + 1 - r..=b_end).collect(),
                device: self.devices[b_end].clone(),
                layer_range: (j, i),
                candidate,
                stage_fps,
                egress_bytes,
                egress_fps: topo.cut_throughput_fps(egress_bytes, this_run, next_run),
            });
        }

        let total_ops: f64 = self
            .net
            .layers
            .iter()
            .filter(|l| l.is_compute())
            .map(|l| l.ops() as f64)
            .sum();
        let plan = ShardPlan {
            network: self.net.name.clone(),
            link: cfg.link,
            fabric: cfg.fabric,
            stages,
            throughput_fps: final_fps,
            gops: final_fps * total_ops / 1e9,
            latency_s: final_latency,
            stats,
        };
        // The DP's incremental mins/sums must agree with the closed-form
        // interleave model bit-for-bit (same operations, same order).
        #[cfg(debug_assertions)]
        {
            let (rates, slots, cuts) = (plan.stage_rates(), plan.slot_runs(), plan.cut_bytes());
            debug_assert_eq!(
                plan.throughput_fps.to_bits(),
                interleave::steady_state_fps_on(&topo, &rates, &slots, &cuts).to_bits(),
                "DP throughput disagrees with the interleave model"
            );
            debug_assert_eq!(
                plan.latency_s.to_bits(),
                interleave::frame_latency_s_on(&topo, &rates, &slots, &cuts).to_bits(),
                "DP latency disagrees with the interleave model"
            );
            // Branch-and-bound must never end below its own incumbent —
            // the incumbent's path survives pruning by construction.
            if let Some(inc) = incumbent {
                if cfg.objective == Objective::Throughput {
                    debug_assert!(
                        plan.throughput_fps >= inc,
                        "B&B lost its incumbent: {} < {}",
                        plan.throughput_fps,
                        inc
                    );
                }
            }
        }
        Some(plan)
    }
}

/// Partition `net` across `devices` (pipeline order), replicating
/// stages up to [`ShardConfig::max_replicas`]-wide where the cluster
/// has contiguous identical boards. Every board is used. Returns `None`
/// when no feasible plan exists — more mandatory stages than compute
/// layers, or some mandatory cell infeasible on its board.
///
/// Deterministic for a fixed [`ShardConfig::seed`] at any
/// [`ShardConfig::threads`]: cells are explored independently (input
/// order restored by [`parallel_map`]) and the DP scan order is fixed.
/// One-shot wrapper over [`Planner`]; sweeps over prefixes should hold
/// a `Planner` instead to reuse its cell memo.
pub fn partition(
    net: &Network,
    devices: &[FpgaDevice],
    cfg: &ShardConfig,
    cache: &EvalCache,
) -> Option<ShardPlan> {
    Planner::new(net, devices, cfg, cache).plan(devices.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::graph::NetworkBuilder;
    use crate::dnn::{zoo, Precision, TensorShape};
    use crate::dse::pso::PsoParams;

    fn vgg(h: usize) -> Network {
        zoo::vgg16_conv(TensorShape::new(3, h, h), Precision::Int16)
    }

    fn quick_cfg() -> ShardConfig {
        ShardConfig {
            pso: PsoParams { population: 8, iterations: 5, ..PsoParams::default() },
            ..ShardConfig::default()
        }
    }

    /// A network dominated by one heavy layer: a contiguous split can
    /// never balance it, which is exactly where replication pays.
    fn bottleneck_net() -> Network {
        NetworkBuilder::new("hotspot", TensorShape::new(3, 64, 64), Precision::Int16)
            .conv(16, 3, 1, 1)
            .conv(256, 3, 1, 1) // the hot layer
            .conv(16, 3, 1, 1)
            .conv(16, 3, 1, 1)
            .build()
    }

    fn assert_plans_bit_identical(a: &ShardPlan, b: &ShardPlan) {
        assert_eq!(a.throughput_fps.to_bits(), b.throughput_fps.to_bits());
        assert_eq!(a.latency_s.to_bits(), b.latency_s.to_bits());
        assert_eq!(a.gops.to_bits(), b.gops.to_bits());
        assert_eq!(a.stages.len(), b.stages.len());
        for (x, y) in a.stages.iter().zip(&b.stages) {
            assert_eq!(x.layer_range, y.layer_range);
            assert_eq!(x.boards, y.boards);
            assert_eq!(x.candidate.rav, y.candidate.rav);
            assert_eq!(x.stage_fps.to_bits(), y.stage_fps.to_bits());
            assert_eq!(x.egress_fps.to_bits(), y.egress_fps.to_bits());
        }
    }

    #[test]
    fn subnetwork_slices_cover_and_chain() {
        let net = vgg(64);
        let n = net.compute_layers().len();
        let a = subnetwork(&net, 0, 6);
        let b = subnetwork(&net, 6, n);
        assert_eq!(a.layers.len() + b.layers.len(), net.layers.len());
        assert_eq!(a.compute_layers().len(), 6);
        assert_eq!(b.compute_layers().len(), n - 6);
        // The cut is shape-consistent: b's first input == a's last output.
        assert_eq!(b.layers[0].input, a.layers.last().unwrap().output);
        a.validate_shapes().unwrap();
        b.validate_shapes().unwrap();
    }

    #[test]
    fn partition_two_boards_covers_all_layers() {
        let net = vgg(64);
        let devices = vec![FpgaDevice::zcu102(), FpgaDevice::zcu102()];
        let cache = EvalCache::new();
        let plan = partition(&net, &devices, &quick_cfg(), &cache).expect("feasible");
        assert_eq!(plan.stages.len(), 2);
        assert_eq!(plan.board_count(), 2);
        assert_eq!(plan.max_replication(), 1);
        assert_eq!(plan.stages[0].layer_range.0, 0);
        assert_eq!(plan.stages[1].layer_range.1, net.compute_layers().len());
        assert_eq!(plan.stages[0].layer_range.1, plan.stages[1].layer_range.0);
        assert_eq!(plan.stages[0].boards, vec![0]);
        assert_eq!(plan.stages[1].boards, vec![1]);
        assert!(plan.throughput_fps > 0.0 && plan.gops > 0.0);
        assert!(plan.latency_s > 0.0);
        assert!(plan.stages[0].egress_bytes > 0.0);
        assert_eq!(plan.stages[1].egress_bytes, 0.0);
        assert!(plan.render().contains("e2e"));
        assert!(plan.render().contains("search:"));
        assert!(plan.stats.cells_evaluated > 0);
        assert!(plan.stats.is_exact(), "p2p never beam-prunes");
    }

    #[test]
    fn more_boards_than_layers_is_none_without_replication() {
        let net = vgg(64);
        let n = net.compute_layers().len();
        let devices = vec![FpgaDevice::zcu102(); n + 1];
        let cache = EvalCache::new();
        assert!(partition(&net, &devices, &quick_cfg(), &cache).is_none());
        // Replication makes the same cluster feasible: stages can share
        // their layer range across boards.
        let mut cfg = quick_cfg();
        cfg.max_replicas = 2;
        let plan = partition(&net, &devices, &cfg, &cache).expect("replication feasible");
        assert_eq!(plan.board_count(), n + 1);
        assert!(plan.max_replication() >= 2);
    }

    #[test]
    fn partition_is_thread_invariant() {
        let net = vgg(64);
        let devices = vec![FpgaDevice::zcu102(), FpgaDevice::zc706()];
        let mut c1 = quick_cfg();
        c1.threads = 1;
        let mut c8 = quick_cfg();
        c8.threads = 8;
        let a = partition(&net, &devices, &c1, &EvalCache::new()).expect("t1");
        let b = partition(&net, &devices, &c8, &EvalCache::new()).expect("t8");
        assert_eq!(a.throughput_fps.to_bits(), b.throughput_fps.to_bits());
        assert_eq!(a.latency_s.to_bits(), b.latency_s.to_bits());
        for (x, y) in a.stages.iter().zip(&b.stages) {
            assert_eq!(x.layer_range, y.layer_range);
            assert_eq!(x.boards, y.boards);
            assert_eq!(x.candidate.rav, y.candidate.rav);
        }
    }

    #[test]
    fn narrow_link_becomes_the_bottleneck() {
        let net = vgg(64);
        let devices = vec![FpgaDevice::ku115(), FpgaDevice::ku115()];
        let mut cfg = quick_cfg();
        // A pathological 1 MB/s link: serialization dominates any cut.
        cfg.link = LinkModel::new(0.001, 1e-6);
        let cache = EvalCache::new();
        let plan = partition(&net, &devices, &cfg, &cache).expect("feasible");
        assert!(plan.bottleneck().starts_with("link"), "{}", plan.bottleneck());
        // And the fast-link plan is strictly faster end-to-end.
        let fast = partition(&net, &devices, &quick_cfg(), &cache).expect("feasible");
        assert!(fast.throughput_fps > plan.throughput_fps);
    }

    #[test]
    fn replication_beats_contiguous_on_a_hotspot() {
        let net = bottleneck_net();
        let devices = vec![FpgaDevice::zcu102(); 4];
        let cache = EvalCache::new();
        let contiguous =
            partition(&net, &devices, &quick_cfg(), &cache).expect("contiguous feasible");
        let mut cfg = quick_cfg();
        cfg.max_replicas = 4;
        let replicated = partition(&net, &devices, &cfg, &cache).expect("replicated feasible");
        assert!(replicated.max_replication() > 1, "planner must actually replicate");
        assert!(
            replicated.gops > contiguous.gops,
            "replicated {} GOP/s must beat contiguous {} GOP/s on a hotspot net",
            replicated.gops,
            contiguous.gops
        );
        // The replica groups tile the cluster exactly, in order.
        let mut next_board = 0usize;
        let mut next_layer = 0usize;
        for s in &replicated.stages {
            assert_eq!(s.boards[0], next_board);
            for (k, &bd) in s.boards.iter().enumerate() {
                assert_eq!(bd, next_board + k);
            }
            next_board += s.replicas();
            assert_eq!(s.layer_range.0, next_layer);
            next_layer = s.layer_range.1;
        }
        assert_eq!(next_board, devices.len());
        assert_eq!(next_layer, net.compute_layers().len());
    }

    #[test]
    fn heterogeneous_boards_never_share_a_replica_group() {
        let net = vgg(64);
        let devices = vec![FpgaDevice::ku115(), FpgaDevice::zc706()];
        let mut cfg = quick_cfg();
        cfg.max_replicas = 2;
        let cache = EvalCache::new();
        let plan = partition(&net, &devices, &cfg, &cache).expect("feasible");
        assert_eq!(plan.max_replication(), 1, "distinct devices cannot replicate");
        assert_eq!(plan.stages.len(), 2);
    }

    #[test]
    fn explicit_p2p_fabric_is_the_default_planner_bitwise() {
        let net = vgg(64);
        let devices = vec![FpgaDevice::zcu102(), FpgaDevice::zcu102()];
        let a = partition(&net, &devices, &quick_cfg(), &EvalCache::new()).expect("default");
        let mut cfg = quick_cfg();
        cfg.fabric = FabricKind::PointToPoint;
        let b = partition(&net, &devices, &cfg, &EvalCache::new()).expect("explicit p2p");
        assert_eq!(a.throughput_fps.to_bits(), b.throughput_fps.to_bits());
        assert_eq!(a.latency_s.to_bits(), b.latency_s.to_bits());
        assert_eq!(a.fabric, FabricKind::PointToPoint);
        // Repricing a p2p plan on p2p is the identity.
        let again = b.repriced_on(FabricKind::PointToPoint);
        assert_eq!(again.throughput_fps.to_bits(), b.throughput_fps.to_bits());
        assert_eq!(again.latency_s.to_bits(), b.latency_s.to_bits());
        assert_eq!(again.gops.to_bits(), b.gops.to_bits());
    }

    #[test]
    fn tight_star_bisection_becomes_the_fabric_bottleneck() {
        let net = vgg(64);
        let devices = vec![FpgaDevice::zcu102(), FpgaDevice::zcu102()];
        let mut cfg = quick_cfg();
        // A 1 MB/s switch: any cut's traffic saturates the fabric.
        cfg.fabric = FabricKind::Star { bisection_gbps: 0.001 };
        let cache = EvalCache::new();
        let plan = partition(&net, &devices, &cfg, &cache).expect("feasible");
        assert_eq!(plan.fabric, cfg.fabric);
        assert_eq!(plan.bottleneck(), "fabric", "{}", plan.bottleneck());
        // The fabric ceiling is exactly bisection / total cut bytes
        // (same resolution path, bit-for-bit).
        let total: f64 = plan.cut_bytes().iter().sum();
        assert!(total > 0.0);
        assert_eq!(plan.throughput_fps.to_bits(), plan.topo().fabric_fps(total).to_bits());
        assert_eq!(plan.throughput_fps.to_bits(), plan.fabric_fps().to_bits());
        // An unconstrained switch on the same structure is faster.
        let fast = plan.repriced_on(FabricKind::Star { bisection_gbps: 100.0 });
        assert!(fast.throughput_fps > plan.throughput_fps);
    }

    #[test]
    fn ring_fabric_single_lane_caps_replicated_cuts() {
        // On a ring, a replicated fan still crosses one boundary link,
        // so repricing a p2p plan with a wide fan onto a ring can only
        // lower (never raise) the modeled rate.
        let net = bottleneck_net();
        let devices = vec![FpgaDevice::zcu102(); 4];
        let mut cfg = quick_cfg();
        cfg.max_replicas = 4;
        let cache = EvalCache::new();
        let p2p = partition(&net, &devices, &cfg, &cache).expect("p2p feasible");
        let on_ring = p2p.repriced_on(FabricKind::Ring);
        assert!(on_ring.throughput_fps <= p2p.throughput_fps);
        // Hop latency grows with slot span, so latency never shrinks.
        assert!(on_ring.latency_s >= p2p.latency_s);
        // And the ring-aware planner never models below the repriced
        // blind plan (its search space contains that structure).
        cfg.fabric = FabricKind::Ring;
        let aware = partition(&net, &devices, &cfg, &cache).expect("ring feasible");
        assert!(aware.throughput_fps >= on_ring.throughput_fps);
    }

    #[test]
    fn max_replicas_one_matches_default_bitwise() {
        let net = vgg(64);
        let devices = vec![FpgaDevice::zcu102(), FpgaDevice::zcu102()];
        let a = partition(&net, &devices, &quick_cfg(), &EvalCache::new()).expect("default");
        let mut cfg = quick_cfg();
        cfg.max_replicas = 1;
        let b = partition(&net, &devices, &cfg, &EvalCache::new()).expect("explicit r=1");
        assert_eq!(a.throughput_fps.to_bits(), b.throughput_fps.to_bits());
        assert_eq!(a.latency_s.to_bits(), b.latency_s.to_bits());
        for (x, y) in a.stages.iter().zip(&b.stages) {
            assert_eq!(x.layer_range, y.layer_range);
            assert_eq!(x.boards, y.boards);
        }
    }

    #[test]
    fn exhaustive_and_bnb_agree_bitwise() {
        // The headline equivalence on a non-trivial instance: 4 boards,
        // replication allowed, a hotspot network where pruning actually
        // fires. The generalized random-instance version lives in
        // `tests/proptests.rs`.
        let net = bottleneck_net();
        let devices = vec![FpgaDevice::zcu102(); 4];
        let mut ex = quick_cfg();
        ex.max_replicas = 4;
        ex.planner = PlannerMode::Exhaustive;
        let mut bb = ex.clone();
        bb.planner = PlannerMode::BranchAndBound;
        let a = partition(&net, &devices, &ex, &EvalCache::new()).expect("exhaustive");
        let b = partition(&net, &devices, &bb, &EvalCache::new()).expect("bnb");
        assert_plans_bit_identical(&a, &b);
        // And the pruned run did strictly less cell work.
        assert!(b.stats.cells_evaluated <= a.stats.cells_evaluated);
        assert!(b.stats.incumbent_fps > 0.0, "incumbent seed must be feasible here");
    }

    #[test]
    fn bnb_prunes_link_starved_ranges_deterministically() {
        // Three layers where the middle conv fans out to 512 channels:
        // cutting *after* it pushes 32× the bytes of cutting before it.
        // Over a 1 MB/s link the late cut's ceiling (an exact bound, no
        // DSE slack involved) sits far below any plan using the early
        // cut, so branch-and-bound must prune the two ranges only the
        // late cut can reach — cell (0..2) and cell (2..3) — while the
        // exhaustive planner evaluates all 4 reachable cells.
        let net = NetworkBuilder::new("fanout", TensorShape::new(3, 64, 64), Precision::Int16)
            .conv(16, 3, 1, 1)
            .conv(512, 3, 1, 1)
            .conv(16, 3, 1, 1)
            .build();
        let devices = vec![FpgaDevice::zcu102(), FpgaDevice::zcu102()];
        let mut ex = quick_cfg();
        ex.link = LinkModel::new(0.001, 1e-6);
        ex.planner = PlannerMode::Exhaustive;
        let mut bb = ex.clone();
        bb.planner = PlannerMode::BranchAndBound;
        let a = partition(&net, &devices, &ex, &EvalCache::new()).expect("exhaustive");
        let b = partition(&net, &devices, &bb, &EvalCache::new()).expect("bnb");
        assert_plans_bit_identical(&a, &b);
        assert_eq!(a.stats.cells_evaluated, 4, "2 first-stage + 2 last-stage cells");
        assert_eq!(b.stats.cells_evaluated, 2, "only the early-cut chain survives the bound");
        assert_eq!(b.stats.cells_pruned, 1, "cell (0..2) is pruned before evaluation");
        assert!(b.stats.transitions_pruned >= 1);
        assert!(b.stats.incumbent_fps > 0.0);
        // Both plans use the early cut — the late cut is link-starved.
        assert_eq!(a.stages[0].layer_range, (0, 1));
    }

    #[test]
    fn planner_memo_reuses_cells_across_prefix_calls() {
        let net = vgg(64);
        let devices = vec![FpgaDevice::zcu102(); 4];
        // Exhaustive makes the cross-prefix cell overlap a structural
        // guarantee (the 4-board wanted set contains 2-board cells);
        // the B&B × memo composition is covered by the proptests.
        let mut cfg = quick_cfg();
        cfg.planner = PlannerMode::Exhaustive;
        let cache = EvalCache::new();
        let mut planner = Planner::new(&net, &devices, &cfg, &cache);
        let p2 = planner.plan(2).expect("2 boards");
        assert_eq!(p2.stats.cells_reused, 0, "first call has nothing to reuse");
        let p4 = planner.plan(4).expect("4 boards");
        assert!(
            p4.stats.cells_reused > 0,
            "the 4-board DP must reuse the 2-board prefix's cells"
        );
        // And the memo-reusing plan equals a fresh single-shot plan.
        let fresh = partition(&net, &devices, &cfg, &EvalCache::new()).expect("fresh");
        assert_plans_bit_identical(&fresh, &p4);
        assert_eq!(planner.total_stats().cells_evaluated, planner.memo_len() as u64);
    }

    #[test]
    fn forced_beam_cap_is_counted_not_silent() {
        // A star fabric with a frontier cap of 1 must beam-prune on any
        // instance whose Pareto sets exceed one entry — and say so.
        let net = vgg(64);
        let devices = vec![FpgaDevice::zcu102(); 3];
        let mut cfg = quick_cfg();
        // Exhaustive mode keeps every Pareto entry (no incumbent
        // filtering), so the overfull frontier is guaranteed: early
        // cuts trade high fps against heavy switch traffic, deep cuts
        // the reverse — incomparable pairs at any mid-board cell.
        cfg.planner = PlannerMode::Exhaustive;
        cfg.fabric = FabricKind::Star { bisection_gbps: 0.05 };
        cfg.fabric_frontier_cap = 1;
        let capped = partition(&net, &devices, &cfg, &EvalCache::new()).expect("feasible");
        assert!(
            capped.stats.frontier_dropped > 0,
            "cap=1 on a contended star must drop frontier entries"
        );
        assert!(!capped.stats.is_exact());
        assert!(capped.render().contains("beam ("));
        // The default cap is generous enough to stay exact here.
        cfg.fabric_frontier_cap = 128;
        let exact = partition(&net, &devices, &cfg, &EvalCache::new()).expect("feasible");
        assert!(exact.stats.is_exact());
        // Exact search never models worse than the beam.
        assert!(exact.throughput_fps >= capped.throughput_fps);
    }
}
