//! Admissible throughput bounds for the cut-point planner's
//! branch-and-bound mode (see `rust/docs/planner.md` for the full
//! derivation and the admissibility argument).
//!
//! Everything here is derived from closed forms only — no DSE runs:
//!
//! * **Cell roof** ([`BoundCtx::cell_fps_ub`]): a stage running compute
//!   layers `[j, i)` on one board cannot exceed the board's compute
//!   roof, `fps ≤ peak_gops · 1e9 / ops(j, i)` with
//!   `peak_gops = α · DSP · f_MHz / 1e3` (the Eq. 1 ceiling the
//!   explorer's `dsp_efficiency ≤ 1` invariant enforces, padded by
//!   [`ADMISSIBILITY_SLACK`] to absorb the explorer's documented
//!   `≤ 1.000001` efficiency tolerance and float-summation noise).
//! * **Forward roof DP** ([`BoundCtx::forward_path`]): the exact DP's
//!   skeleton run over cell roofs and real link ceilings instead of
//!   explored designs. Its argmax path is the *incumbent seed*: the
//!   planner evaluates just that path's cells exactly and uses the
//!   resulting real plan score as the pruning incumbent.
//! * **Suffix roof DP** ([`BoundCtx::suffix`]): for every DP state
//!   `(b, i, r)` — a stage ending at board `b`, layers `[0, i)` done,
//!   last stage `r`-wide — an upper bound on the `min` of all *future*
//!   stage and link terms of any completion. `-∞` marks states with no
//!   structural completion at all.
//!
//! The shared-fabric term (`bisection / Σ cut_bytes` on a star) only
//! ever lowers a plan's final score, so ignoring it keeps every bound
//! admissible.

use crate::topo::{SlotRun, Topology};

/// Multiplier padding the compute-roof bound. The explorer pins
/// `dsp_efficiency ≤ 1.000001` (see `prop_candidate_efficiency_bounded`)
/// and its unit tests tolerate `≤ 1.01`; 1.05 keeps the bound an upper
/// bound with a wide margin while costing almost no pruning power.
pub const ADMISSIBILITY_SLACK: f64 = 1.05;

/// Marker for "no feasible value": any real bound compares `>` it, and
/// NaN (which should never appear) fails the comparison and is treated
/// as unset too.
const UNSET: f64 = f64::NEG_INFINITY;

fn is_set(v: f64) -> bool {
    v > UNSET
}

/// Upper bound on the `min` of all remaining stage/link terms from each
/// DP state, indexed `(b, i, r)`; see [`BoundCtx::suffix`].
pub struct SuffixBound {
    vals: Vec<f64>,
    n: usize,
    maxr: usize,
}

impl SuffixBound {
    fn idx(&self, b: usize, i: usize, r: usize) -> usize {
        (b * (self.n + 1) + i) * (self.maxr + 1) + r
    }

    /// Bound for the state "last stage ended at board `b`, `r`-wide,
    /// compute layers `[0, i)` covered". `+∞` for the terminal state,
    /// `-∞` when no structural completion exists.
    pub fn get(&self, b: usize, i: usize, r: usize) -> f64 {
        self.vals[self.idx(b, i, r)]
    }
}

/// Everything the bound DPs need about one planning instance — borrowed
/// views of the planner's precomputed per-cluster/per-network tables.
pub struct BoundCtx<'a> {
    /// Boards in this prefix.
    pub k: usize,
    /// Compute-layer count.
    pub n: usize,
    /// Effective replication cap (already clamped to `k`).
    pub maxr: usize,
    /// Canonical device slot per board (`k` entries).
    pub slot: &'a [usize],
    /// Same-device run length ending at each board (`k` entries).
    pub run_len: &'a [usize],
    /// Prefix sums of compute-layer ops (`n + 1` entries, ops in f64).
    pub ops_pfx: &'a [f64],
    /// Per-slot `ADMISSIBILITY_SLACK · peak_gops · 1e9` numerator.
    pub peak_fps_num: &'a [f64],
    /// Bytes on the wire at each cut (`n + 1` entries).
    pub cut_bytes: &'a [f64],
    pub topo: &'a Topology,
}

impl BoundCtx<'_> {
    fn min_stages(&self, boards: usize) -> usize {
        boards.div_ceil(self.maxr)
    }

    fn idx(&self, b: usize, i: usize, r: usize) -> usize {
        (b * (self.n + 1) + i) * (self.maxr + 1) + r
    }

    /// Admissible per-replica fps roof of a stage running compute layers
    /// `[j, i)` on a board of device-slot `s`.
    pub fn cell_fps_ub(&self, s: usize, j: usize, i: usize) -> f64 {
        let ops = self.ops_pfx[i] - self.ops_pfx[j];
        if ops > 0.0 {
            self.peak_fps_num[s] / ops
        } else {
            f64::INFINITY
        }
    }

    /// The exact DP skeleton run over roofs: best optimistic end-to-end
    /// rate per state, with parent pointers. Returns the argmax terminal
    /// path as `(start_layer, end_layer, last_board, replicas)` stages
    /// in pipeline order, or `None` when the instance is structurally
    /// infeasible.
    pub fn forward_path(&self) -> Option<Vec<(usize, usize, usize, usize)>> {
        let (k, n, maxr) = (self.k, self.n, self.maxr);
        if k == 0 || n == 0 || self.min_stages(k) > n {
            return None;
        }
        let sz = k * (n + 1) * (maxr + 1);
        let mut fwd = vec![UNSET; sz];
        let mut par: Vec<(usize, usize)> = vec![(0, 0); sz];
        for b in 0..k {
            let rmax = maxr.min(self.run_len[b]).min(b + 1);
            let after = k - 1 - b;
            if self.min_stages(after) >= n {
                continue;
            }
            let i_max = n - self.min_stages(after);
            for i in 1..=i_max {
                if b == k - 1 && i != n {
                    continue;
                }
                for r in 1..=rmax {
                    let before = b + 1 - r;
                    if before == 0 {
                        fwd[self.idx(b, i, r)] = r as f64 * self.cell_fps_ub(self.slot[b], 0, i);
                        continue;
                    }
                    let pb = before - 1;
                    let cur_run = SlotRun::new(before, r);
                    let mut best = UNSET;
                    let mut best_par = (0usize, 0usize);
                    for j in self.min_stages(before).max(1)..i {
                        let roof = r as f64 * self.cell_fps_ub(self.slot[b], j, i);
                        for r_prev in 1..=maxr.min(self.run_len[pb]).min(pb + 1) {
                            let fp = fwd[self.idx(pb, j, r_prev)];
                            if !is_set(fp) {
                                continue;
                            }
                            let prev_run = SlotRun::new(before - r_prev, r_prev);
                            let link =
                                self.topo.cut_throughput_fps(self.cut_bytes[j], prev_run, cur_run);
                            let cand = fp.min(link).min(roof);
                            if cand > best {
                                best = cand;
                                best_par = (j, r_prev);
                            }
                        }
                    }
                    if is_set(best) {
                        fwd[self.idx(b, i, r)] = best;
                        par[self.idx(b, i, r)] = best_par;
                    }
                }
            }
        }
        let mut best_r = 0usize;
        let mut best_v = UNSET;
        for r in 1..=maxr.min(self.run_len[k - 1]).min(k) {
            let v = fwd[self.idx(k - 1, n, r)];
            if v > best_v {
                best_v = v;
                best_r = r;
            }
        }
        if best_r == 0 {
            return None;
        }
        let mut rev: Vec<(usize, usize, usize, usize)> = Vec::new();
        let (mut b, mut i, mut r) = (k - 1, n, best_r);
        loop {
            let before = b + 1 - r;
            if before == 0 {
                rev.push((0, i, b, r));
                break;
            }
            let (j, r_prev) = par[self.idx(b, i, r)];
            rev.push((j, i, b, r));
            b -= r;
            i = j;
            r = r_prev;
        }
        rev.reverse();
        Some(rev)
    }

    /// Reverse roof DP: for each state `(b, i, r)`, the optimistic `min`
    /// over every structural completion's remaining link and stage
    /// terms. Exact link ceilings (they need no DSE) keep the bound
    /// tight; cell roofs keep it admissible.
    pub fn suffix(&self) -> SuffixBound {
        let (k, n, maxr) = (self.k, self.n, self.maxr);
        let mut vals = vec![UNSET; k * (n + 1) * (maxr + 1)];
        for b in (0..k).rev() {
            for i in 1..=n {
                for r in 1..=maxr.min(self.run_len[b]).min(b + 1) {
                    if b == k - 1 {
                        if i == n {
                            vals[self.idx(b, i, r)] = f64::INFINITY;
                        }
                        continue;
                    }
                    if i == n {
                        continue; // layers exhausted with boards left
                    }
                    let cur_run = SlotRun::new(b + 1 - r, r);
                    let mut best = UNSET;
                    for r2 in 1..=maxr {
                        let b2 = b + r2;
                        if b2 >= k {
                            break;
                        }
                        if self.run_len[b2] < r2 {
                            continue; // boards b+1..=b2 are not one device run
                        }
                        let next_run = SlotRun::new(b + 1, r2);
                        let link =
                            self.topo.cut_throughput_fps(self.cut_bytes[i], cur_run, next_run);
                        let after2 = k - 1 - b2;
                        if b2 == k - 1 {
                            let cand = link
                                .min(r2 as f64 * self.cell_fps_ub(self.slot[b2], i, n))
                                .min(vals[self.idx(b2, n, r2)]);
                            best = best.max(cand);
                        } else {
                            if self.min_stages(after2) >= n {
                                continue;
                            }
                            let i2_max = n - self.min_stages(after2);
                            for i2 in (i + 1)..=i2_max {
                                let cand = link
                                    .min(r2 as f64 * self.cell_fps_ub(self.slot[b2], i, i2))
                                    .min(vals[self.idx(b2, i2, r2)]);
                                best = best.max(cand);
                            }
                        }
                    }
                    vals[self.idx(b, i, r)] = best;
                }
            }
        }
        SuffixBound { vals, n, maxr }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::link::LinkModel;
    use crate::topo::{FabricKind, Topology};

    /// 2 homogeneous boards, 3 equal compute layers, maxr 1.
    fn tiny() -> (Vec<usize>, Vec<usize>, Vec<f64>, Vec<f64>, Vec<f64>) {
        let slot = vec![0, 0];
        let run_len = vec![1, 2];
        let ops_pfx = vec![0.0, 1e9, 2e9, 3e9];
        // 100 GOP/s roof (pre-slack numerator).
        let peak = vec![ADMISSIBILITY_SLACK * 100.0 * 1e9];
        let cut_bytes = vec![0.0, 1024.0, 2048.0, 0.0];
        (slot, run_len, ops_pfx, peak, cut_bytes)
    }

    #[test]
    fn forward_path_covers_layers_and_boards() {
        let (slot, run_len, ops_pfx, peak, cut_bytes) = tiny();
        let topo = Topology::new(LinkModel::default(), FabricKind::PointToPoint);
        let bc = BoundCtx {
            k: 2,
            n: 3,
            maxr: 1,
            slot: &slot,
            run_len: &run_len,
            ops_pfx: &ops_pfx,
            peak_fps_num: &peak,
            cut_bytes: &cut_bytes,
            topo: &topo,
        };
        let path = bc.forward_path().expect("feasible");
        assert_eq!(path.len(), 2);
        assert_eq!(path[0].0, 0, "first stage starts at layer 0");
        assert_eq!(path.last().unwrap().1, 3, "last stage ends at layer n");
        assert_eq!(path.last().unwrap().2, 1, "last stage ends at board k-1");
        // Roofs are equal-ops symmetric, so the balanced cut 0..1|1..3
        // or 0..2|2..3 both roof at 100/2 * slack ... just check the
        // bound value behaves like an upper bound of the best split:
        let suffix = bc.suffix();
        // Terminal state is infinitely completable; a done-early state
        // is not completable at all.
        assert!(suffix.get(1, 3, 1).is_infinite());
        assert!(!is_set(suffix.get(0, 3, 1)));
        // A mid state must carry a finite positive completion bound.
        assert!(suffix.get(0, 1, 1) > 0.0);
    }

    #[test]
    fn cell_roof_scales_inversely_with_ops() {
        let (slot, run_len, ops_pfx, peak, cut_bytes) = tiny();
        let topo = Topology::new(LinkModel::default(), FabricKind::PointToPoint);
        let bc = BoundCtx {
            k: 2,
            n: 3,
            maxr: 1,
            slot: &slot,
            run_len: &run_len,
            ops_pfx: &ops_pfx,
            peak_fps_num: &peak,
            cut_bytes: &cut_bytes,
            topo: &topo,
        };
        let one = bc.cell_fps_ub(0, 0, 1);
        let three = bc.cell_fps_ub(0, 0, 3);
        assert!(one > three);
        assert!((one / three - 3.0).abs() < 1e-12);
        // 1 GOP at a (slack-padded) 100 GOP/s roof.
        assert!((one - ADMISSIBILITY_SLACK * 100.0).abs() < 1e-9);
    }

    #[test]
    fn infeasible_instances_have_no_path() {
        let (slot, run_len, ops_pfx, peak, cut_bytes) = tiny();
        let topo = Topology::new(LinkModel::default(), FabricKind::PointToPoint);
        // 5 mandatory stages > 3 layers.
        let bc = BoundCtx {
            k: 5,
            n: 3,
            maxr: 1,
            slot: &slot,
            run_len: &run_len,
            ops_pfx: &ops_pfx,
            peak_fps_num: &peak,
            cut_bytes: &cut_bytes,
            topo: &topo,
        };
        assert!(bc.forward_path().is_none());
    }
}
