//! Inter-board link presets and cut-tensor accounting.
//!
//! The model itself ([`LinkModel`]) lives in [`crate::perfmodel::link`]
//! next to the other analytical models; this module adds the catalogue
//! of links a deployment would actually provision and the helper that
//! converts a cut boundary into bytes on the wire.

use crate::dnn::{Precision, TensorShape};
pub use crate::perfmodel::link::LinkModel;

/// 100 GbE NIC-to-NIC: ~12 GB/s sustained payload, 2 µs hop.
pub fn eth_100g() -> LinkModel {
    LinkModel::new(12.0, 2e-6)
}

/// Xilinx Aurora 64B/66B over 4 GTY lanes: ~10 GB/s, sub-µs hop — the
/// standard FPGA-to-FPGA serial fabric for tightly-coupled boards.
pub fn aurora_4lane() -> LinkModel {
    LinkModel::new(10.0, 0.5e-6)
}

/// PCIe Gen3 x16 through a host root complex: ~12.8 GB/s payload but a
/// fat 5 µs hop (two DMA traversals + host memcpy).
pub fn pcie_gen3_host() -> LinkModel {
    LinkModel::new(12.8, 5e-6)
}

/// Bytes of one activation tensor of shape `t` at precision `dw` — what
/// a cut whose boundary tensor is `t` puts on the wire per frame.
pub fn tensor_bytes(t: &TensorShape, dw: Precision) -> f64 {
    t.elems() as f64 * dw.bytes()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_are_ordered_sensibly() {
        // Aurora has the lowest hop latency; host PCIe the highest.
        assert!(aurora_4lane().latency_s < eth_100g().latency_s);
        assert!(eth_100g().latency_s < pcie_gen3_host().latency_s);
        for l in [eth_100g(), aurora_4lane(), pcie_gen3_host()] {
            assert!(l.bandwidth_gbps > 0.0 && l.latency_s > 0.0);
        }
    }

    #[test]
    fn tensor_bytes_counts_elements() {
        let t = TensorShape::new(512, 28, 28);
        assert_eq!(tensor_bytes(&t, Precision::Int16), 512.0 * 28.0 * 28.0 * 2.0);
        assert_eq!(tensor_bytes(&t, Precision::Int8), 512.0 * 28.0 * 28.0);
    }
}
