//! External-memory model: burst-efficiency curve + per-transaction
//! overhead.
//!
//! Real DDR subsystems deliver their peak bandwidth only for long
//! sequential bursts; short transfers pay row-activate / precharge /
//! arbitration overhead. We model an AXI-attached DDR controller with a
//! fixed per-transaction latency and an efficiency that saturates with
//! transfer length — the dominant second-order effect separating
//! board-level numbers from closed-form estimates.


/// DRAM timing model.
#[derive(Debug, Clone)]
pub struct DramModel {
    /// Peak bandwidth, bytes per second.
    pub peak_bytes_per_s: f64,
    /// Accelerator clock, Hz (transactions are timed in these cycles).
    pub clock_hz: f64,
    /// Fixed cycles per transaction (command + row overhead).
    pub txn_overhead_cycles: f64,
    /// Burst length in bytes at which efficiency reaches ~63% of peak.
    pub burst_knee_bytes: f64,
}

impl DramModel {
    /// Model for a device's DDR subsystem at a given accelerator clock.
    pub fn new(peak_gbps: f64, clock_mhz: f64) -> Self {
        Self {
            peak_bytes_per_s: peak_gbps * 1e9,
            clock_hz: clock_mhz * 1e6,
            txn_overhead_cycles: 30.0,
            burst_knee_bytes: 512.0,
        }
    }

    /// Effective efficiency (0..1) for a transfer of `bytes`.
    pub fn efficiency(&self, bytes: f64) -> f64 {
        if bytes <= 0.0 {
            return 0.0;
        }
        // Saturating curve: eff = b / (b + knee); long bursts -> ~0.95 cap
        // (refresh + arbitration keep real controllers off 100%).
        0.95 * bytes / (bytes + self.burst_knee_bytes)
    }

    /// Cycles to move `bytes` as `txns` separate transactions.
    pub fn transfer_cycles(&self, bytes: f64, txns: f64) -> f64 {
        if bytes <= 0.0 {
            return 0.0;
        }
        let txns = txns.max(1.0);
        let per_txn = bytes / txns;
        let eff = self.efficiency(per_txn);
        let stream = bytes / (self.peak_bytes_per_s * eff.max(1e-6)) * self.clock_hz;
        stream + self.txn_overhead_cycles * txns
    }

    /// Seconds to move `bytes` as `txns` transactions.
    pub fn transfer_seconds(&self, bytes: f64, txns: f64) -> f64 {
        self.transfer_cycles(bytes, txns) / self.clock_hz
    }

    /// Scale the model's peak bandwidth (for RAV partitioning).
    pub fn with_bandwidth_share(&self, share_gbps: f64) -> Self {
        let mut m = self.clone();
        m.peak_bytes_per_s = share_gbps * 1e9;
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn long_bursts_approach_peak() {
        let m = DramModel::new(19.2, 200.0);
        let eff = m.efficiency((1u64 << 20) as f64);
        assert!(eff > 0.9 && eff <= 0.95, "eff {eff}");
    }

    #[test]
    fn short_bursts_penalized() {
        let m = DramModel::new(19.2, 200.0);
        assert!(m.efficiency(64.0) < 0.2);
        // Same bytes in many transactions is slower.
        let one = m.transfer_cycles(1e6, 1.0);
        let many = m.transfer_cycles(1e6, 1000.0);
        assert!(many > one, "many {many} one {one}");
    }

    #[test]
    fn zero_bytes_free() {
        let m = DramModel::new(19.2, 200.0);
        assert_eq!(m.transfer_cycles(0.0, 5.0), 0.0);
    }

    #[test]
    fn bandwidth_share_scales() {
        let m = DramModel::new(19.2, 200.0);
        let half = m.with_bandwidth_share(9.6);
        let t_full = m.transfer_seconds(1e7, 10.0);
        let t_half = half.transfer_seconds(1e7, 10.0);
        assert!(t_half > t_full * 1.8, "half {t_half} full {t_full}");
    }
}
