//! Lightweight event trace for simulator runs (debugging + metrics).


/// Kinds of simulator events.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    ComputeStart,
    ComputeEnd,
    DramRead,
    DramWrite,
    Stall,
    StageHandoff,
}

/// One trace record: (cycle, unit, kind, bytes-if-memory).
#[derive(Debug, Clone)]
pub struct Event {
    pub cycle: u64,
    pub unit: String,
    pub kind: EventKind,
    pub bytes: f64,
}

/// Bounded trace buffer; recording can be disabled for benchmarking.
#[derive(Debug, Default)]
pub struct Trace {
    pub events: Vec<Event>,
    pub enabled: bool,
    pub capacity: usize,
}

impl Trace {
    pub fn disabled() -> Self {
        Self { events: Vec::new(), enabled: false, capacity: 0 }
    }

    pub fn enabled(capacity: usize) -> Self {
        Self { events: Vec::with_capacity(capacity.min(1 << 16)), enabled: true, capacity }
    }

    pub fn record(&mut self, cycle: u64, unit: &str, kind: EventKind, bytes: f64) {
        if self.enabled && self.events.len() < self.capacity {
            self.events.push(Event { cycle, unit: unit.to_string(), kind, bytes });
        }
    }

    /// Total bytes across DRAM events.
    pub fn dram_bytes(&self) -> f64 {
        self.events
            .iter()
            .filter(|e| matches!(e.kind, EventKind::DramRead | EventKind::DramWrite))
            .map(|e| e.bytes)
            .sum()
    }

    /// Count of stall events.
    pub fn stalls(&self) -> usize {
        self.events.iter().filter(|e| e.kind == EventKind::Stall).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_trace_records_nothing() {
        let mut t = Trace::disabled();
        t.record(1, "u", EventKind::Stall, 0.0);
        assert!(t.events.is_empty());
    }

    #[test]
    fn capacity_bounds_recording() {
        let mut t = Trace::enabled(2);
        for i in 0..5 {
            t.record(i, "u", EventKind::DramRead, 10.0);
        }
        assert_eq!(t.events.len(), 2);
        assert_eq!(t.dram_bytes(), 20.0);
        assert_eq!(t.stalls(), 0);
    }
}
