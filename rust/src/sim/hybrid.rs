//! Whole-system simulation of a hybrid candidate: pipeline structure and
//! generic structure running concurrently (on consecutive batches —
//! Fig. 5's dataflow), sharing the external memory.
//!
//! The two structures contend for DRAM: the pipeline's weight/input
//! streams get the RAV's `BW_p` share, the generic structure the rest
//! (the paper's static bandwidth partitioning). The steady-state system
//! period is the slower structure's simulated batch period; the handoff
//! buffer (the generic structure's feature-map buffer fed by the last
//! pipeline stage) is checked for capacity.

use crate::dnn::{Layer, Network};
use crate::dse::engine::Candidate;
use crate::fpga::FpgaDevice;
use crate::sim::dram::DramModel;
use crate::sim::trace::Trace;
use crate::sim::{simulate_generic, simulate_pipeline, SimResult};

/// System-level simulated result for a hybrid candidate.
#[derive(Debug, Clone)]
pub struct HybridSimResult {
    pub pipeline: Option<SimResult>,
    pub generic: Option<SimResult>,
    /// Steady-state frames/s of the whole accelerator.
    pub fps: f64,
    /// Sustained GOP/s over the whole network.
    pub gops: f64,
    /// Which structure bounds the system ("pipeline" | "generic").
    pub bottleneck: &'static str,
    /// Whether the handoff feature map fits the generic fm buffer.
    pub handoff_fits: bool,
}

/// Simulate an explored candidate end to end on a device.
pub fn simulate_candidate(
    net: &Network,
    device: &FpgaDevice,
    cand: &Candidate,
    trace: &mut Trace,
) -> anyhow::Result<HybridSimResult> {
    let layers: Vec<&Layer> = net.layers.iter().filter(|l| l.is_compute()).collect();
    let sp = cand.rav.sp.min(layers.len());
    let batch = cand.rav.batch.max(1);

    let mut p_res = None;
    let mut p_period = 0.0f64;
    if let Some(p) = &cand.pipeline {
        let dram = DramModel::new(
            device.bandwidth_gbps * cand.rav.bw_frac,
            device.freq_mhz,
        );
        let r = simulate_pipeline(&layers[..sp], &p.config, &dram, trace)?;
        p_period = batch as f64 / r.fps;
        p_res = Some(r);
    }

    let mut g_res = None;
    let mut g_period = 0.0f64;
    let mut handoff_fits = true;
    if let Some(g) = &cand.generic {
        let bw_g = if sp > 0 {
            device.bandwidth_gbps * (1.0 - cand.rav.bw_frac)
        } else {
            device.bandwidth_gbps
        };
        let dram = DramModel::new(bw_g, device.freq_mhz);
        let r = simulate_generic(&layers[sp..], &g.config, &dram, batch, trace)?;
        g_period = batch as f64 / r.fps;
        g_res = Some(r);
        // Handoff: the first generic layer's input map must fit half the
        // fm buffer (ping-pong against the pipeline writer).
        if sp > 0 && sp < layers.len() {
            let bits = layers[sp].ifm_bytes(g.config.dw) * 8.0;
            handoff_fits = bits <= g.config.cap_fm_bits / 2.0;
        }
    }

    let period = p_period.max(g_period);
    anyhow::ensure!(period > 0.0, "candidate has neither structure");
    let fps = batch as f64 / period;
    let ops: f64 = layers.iter().map(|l| l.ops() as f64).sum();
    Ok(HybridSimResult {
        pipeline: p_res,
        generic: g_res,
        fps,
        gops: fps * ops / 1e9,
        bottleneck: if p_period >= g_period { "pipeline" } else { "generic" },
        handoff_fits,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::{zoo, Precision, TensorShape};
    use crate::dse::rav::Rav;
    use crate::dse::{engine, ExplorerConfig};

    fn candidate(sp: usize) -> (crate::Network, FpgaDevice, Candidate) {
        let net = zoo::vgg16_conv(TensorShape::new(3, 224, 224), Precision::Int16);
        let device = FpgaDevice::ku115();
        let cfg = ExplorerConfig::new(device.clone());
        let rav = Rav { sp, batch: 1, dsp_frac: 0.5, bram_frac: 0.5, bw_frac: 0.5 };
        let cand = engine::evaluate(&net, &cfg, rav).expect("feasible");
        (net, device, cand)
    }

    #[test]
    fn simulated_close_to_analytical_system_estimate() {
        let (net, device, cand) = candidate(6);
        let sim =
            simulate_candidate(&net, &device, &cand, &mut Trace::disabled()).unwrap();
        let err = (sim.gops - cand.gops).abs() / cand.gops;
        assert!(
            err < 0.25,
            "system sim {:.0} vs analytical {:.0} ({err:.2})",
            sim.gops,
            cand.gops
        );
        assert!(sim.handoff_fits);
    }

    #[test]
    fn pure_extremes_simulate() {
        for sp in [0usize, 13] {
            let (net, device, cand) = candidate(sp);
            let sim =
                simulate_candidate(&net, &device, &cand, &mut Trace::disabled()).unwrap();
            assert!(sim.fps > 0.0, "sp={sp}");
            if sp == 0 {
                assert!(sim.pipeline.is_none() && sim.generic.is_some());
                assert_eq!(sim.bottleneck, "generic");
            } else {
                assert!(sim.pipeline.is_some() && sim.generic.is_none());
                assert_eq!(sim.bottleneck, "pipeline");
            }
        }
    }

    #[test]
    fn trace_captures_both_structures() {
        let (net, device, cand) = candidate(4);
        let mut trace = Trace::enabled(4096);
        simulate_candidate(&net, &device, &cand, &mut trace).unwrap();
        assert!(trace.dram_bytes() > 0.0);
        assert!(!trace.events.is_empty());
    }
}
