//! Discrete-event simulation of a replicated, frame-interleaved shard
//! plan — the independent check on [`crate::perfmodel::interleave`].
//!
//! Where the analytic model reasons in closed form (`min` over effective
//! stage rates, per-cut topology ceilings, and the shared-fabric term),
//! this simulator walks every frame through every resource it occupies:
//!
//! * each **replica** is a serial server (one frame at a time, service
//!   time = the stage's per-frame interval), frames assigned round-robin
//!   by global frame index;
//! * each cut crossing occupies the links its [`Topology`] resolves:
//!   on `p2p`/`mesh`/`star` the producer replica's **egress link** and
//!   the consumer replica's **ingress link** jointly for the
//!   serialization time; on a **ring** the cut's single shared boundary
//!   segment; on a **star** additionally the switch — one shared
//!   store-and-forward station whose busy time per crossing is
//!   `bytes / bisection`, so concurrent cuts jointly saturate at the
//!   aggregate bandwidth (the fabric-contention term of the analytic
//!   model). The fabric's fixed hop latency is then added as pure delay;
//! * departures are **re-ordered**: frame `k` leaves the pipeline only
//!   after every frame `< k` has left (exactly what the coordinator's
//!   reorder buffer does).
//!
//! Everything is deterministic, so the steady state is exact up to the
//! warm-up transient; `tests/sim_vs_model.rs` asserts the measured rate
//! matches the analytic prediction within a small tolerance for a grid
//! of plan shapes *and fabrics* (p2p, ring, star), and that the live
//! [`crate::coordinator::ShardedPipeline`] agrees with both.

use crate::perfmodel::interleave::StageRate;
use crate::shard::ShardPlan;
use crate::topo::{FabricKind, SlotRun, Topology};

/// One simulated stage: `replicas` identical serial servers.
#[derive(Debug, Clone, Copy)]
pub struct SimStage {
    pub replicas: usize,
    /// Per-frame service time of one replica, seconds (the stage's
    /// steady-state interval, `1 / fps`).
    pub service_s: f64,
}

/// A simulated plan: stages in pipeline order, the interconnect every
/// cut resolves against, and the bytes on the wire at each internal cut
/// (`cut_bytes.len() == stages.len() - 1`). Replica groups are placed
/// in stage order (stage 0 on the lowest board slots), exactly as the
/// shard planner tiles a cluster.
#[derive(Debug, Clone)]
pub struct ShardSimSpec {
    pub stages: Vec<SimStage>,
    pub topo: Topology,
    pub cut_bytes: Vec<f64>,
}

impl ShardSimSpec {
    /// Derive the simulation spec from a planned [`ShardPlan`]: each
    /// replica serves at the candidate's modeled interval, over the
    /// plan's own topology.
    pub fn from_plan(plan: &ShardPlan) -> Self {
        Self {
            stages: plan
                .stages
                .iter()
                .map(|s| SimStage {
                    replicas: s.replicas(),
                    service_s: 1.0 / s.candidate.throughput_fps.max(1e-12),
                })
                .collect(),
            topo: plan.topo(),
            cut_bytes: plan.cut_bytes(),
        }
    }

    /// The same spec as the analytic model sees it (latency per stage =
    /// service time; the DES has no separate fill model).
    pub fn stage_rates(&self) -> Vec<StageRate> {
        self.stages
            .iter()
            .map(|s| StageRate::new(s.replicas, 1.0 / s.service_s.max(1e-12), s.service_s))
            .collect()
    }

    /// Stage-order board placement: stage `s` occupies the next
    /// `replicas` slots (the same tiling the analytic model and the
    /// planner use — one source of truth in `interleave::chain_slots`).
    pub fn slot_runs(&self) -> Vec<SlotRun> {
        crate::perfmodel::interleave::chain_slots(&self.stage_rates())
    }
}

/// What the simulation measured.
#[derive(Debug, Clone)]
pub struct ShardSimResult {
    /// Steady-state frame rate over the post-warm-up window, using
    /// re-ordered (in-order) departures.
    pub throughput_fps: f64,
    /// Approximate pipeline fill delay: mean in-order departure time of
    /// post-warm-up frames minus the mean ideal injection time
    /// (`k / throughput`), clamped at 0. Under the saturated source all
    /// admissions are at t = 0, so a literal sojourn would grow
    /// linearly with frame index — this subtracts that ramp. For
    /// single-frame latency use [`crate::perfmodel::interleave::
    /// frame_latency_s`]; this field is a coarse transient diagnostic.
    pub mean_latency_s: f64,
    /// In-order departure instants of every simulated frame (seconds
    /// from the first admission); non-decreasing by construction.
    pub departures_s: Vec<f64>,
    /// Frames simulated (== departures_s.len(); conservation check).
    pub frames: usize,
}

/// Per-cut resources as the topology resolves them, precomputed once.
struct CutRes {
    bytes: f64,
    /// Per-lane serialization time of one crossing.
    ser_s: f64,
    /// Pure delay added after serialization (hop latency, per fabric).
    hop_s: f64,
    /// Store-and-forward busy time on the shared switch (0 off star).
    fabric_ser_s: f64,
    /// Ring: all crossings share the cut's single boundary segment
    /// instead of per-replica endpoint links.
    shared_boundary: bool,
}

/// Simulate `frames` frames through `spec` with an always-full input
/// queue (saturation — the steady-state throughput measurement), using
/// the first `warmup` frames to fill the pipeline before measuring.
pub fn simulate_shard(
    spec: &ShardSimSpec,
    frames: usize,
    warmup: usize,
) -> anyhow::Result<ShardSimResult> {
    anyhow::ensure!(!spec.stages.is_empty(), "empty shard pipeline");
    anyhow::ensure!(
        spec.cut_bytes.len() + 1 == spec.stages.len(),
        "cut/stage count mismatch: {} cuts for {} stages",
        spec.cut_bytes.len(),
        spec.stages.len()
    );
    anyhow::ensure!(frames > warmup + 1, "need more frames than warmup");
    for s in &spec.stages {
        anyhow::ensure!(s.replicas >= 1 && s.service_s > 0.0, "degenerate stage {s:?}");
    }

    let topo = &spec.topo;
    let slots = spec.slot_runs();
    let link_bytes_per_s = topo.link.bandwidth_bytes().max(1.0);
    let fabric_bytes_per_s = topo.fabric_bytes_per_s();
    let cuts: Vec<CutRes> = spec
        .cut_bytes
        .iter()
        .enumerate()
        .map(|(s, &bytes)| CutRes {
            bytes,
            ser_s: bytes / link_bytes_per_s,
            hop_s: topo.cut_hop_s(slots[s], slots[s + 1]),
            fabric_ser_s: fabric_bytes_per_s.map(|b| bytes / b).unwrap_or(0.0),
            shared_boundary: matches!(topo.kind, FabricKind::Ring),
        })
        .collect();

    // Per-resource next-free times. Round-robin by global frame index
    // fixes each frame's replica at every stage, so every resource
    // serves its frames in ascending frame order — a greedy in-order
    // pass over frames is an exact discrete-event schedule. (The shared
    // switch also serves crossings in ascending frame order under this
    // pass; its busy time per frame is the frame's total switched
    // bytes / bisection, so the saturated rate matches the analytic
    // `bisection / Σ cut_bytes` ceiling.)
    let mut replica_free: Vec<Vec<f64>> =
        spec.stages.iter().map(|s| vec![0.0; s.replicas]).collect();
    let mut egress_free: Vec<Vec<f64>> =
        spec.stages.iter().map(|s| vec![0.0; s.replicas]).collect();
    let mut ingress_free: Vec<Vec<f64>> =
        spec.stages.iter().map(|s| vec![0.0; s.replicas]).collect();
    // Ring boundary segment per cut, and the star's shared switch.
    let mut boundary_free: Vec<f64> = vec![0.0; cuts.len()];
    let mut fabric_free = 0.0f64;

    let mut completions = Vec::with_capacity(frames);
    for k in 0..frames {
        // Saturated source: every frame is ready at t = 0.
        let mut t = 0.0f64;
        for (s, stage) in spec.stages.iter().enumerate() {
            let q = k % stage.replicas;
            // Serve on this stage's replica.
            let start = t.max(replica_free[s][q]);
            t = start + stage.service_s;
            replica_free[s][q] = t;
            // Cross the cut to the next stage, if any. A zero-byte cut
            // costs nothing, matching `Topology::cut_transfer_s(0) == 0`.
            if s + 1 < spec.stages.len() {
                let cut = &cuts[s];
                if cut.bytes > 0.0 {
                    let mut end = if cut.shared_boundary {
                        // Ring: one boundary segment carries the whole
                        // cut regardless of the replica fan.
                        let start = t.max(boundary_free[s]);
                        let end = start + cut.ser_s;
                        boundary_free[s] = end;
                        end
                    } else {
                        // The transfer occupies both endpoints jointly.
                        let c = k % spec.stages[s + 1].replicas;
                        let start = t.max(egress_free[s][q]).max(ingress_free[s + 1][c]);
                        let end = start + cut.ser_s;
                        egress_free[s][q] = end;
                        ingress_free[s + 1][c] = end;
                        end
                    };
                    if cut.fabric_ser_s > 0.0 {
                        // Store-and-forward through the shared switch:
                        // its busy time accumulates across all cuts.
                        let fstart = end.max(fabric_free);
                        fabric_free = fstart + cut.fabric_ser_s;
                        end = fabric_free;
                    }
                    t = end + cut.hop_s;
                }
            }
        }
        completions.push(t);
    }

    // Reorder: frame k departs once every frame < k has (the dispatcher's
    // in-order delivery guarantee).
    let mut departures = Vec::with_capacity(frames);
    let mut horizon = 0.0f64;
    for &c in &completions {
        horizon = horizon.max(c);
        departures.push(horizon);
    }

    let span = departures[frames - 1] - departures[warmup];
    anyhow::ensure!(span > 0.0, "degenerate simulation span");
    let measured = (frames - 1 - warmup) as f64 / span;
    let mean_latency = departures[warmup..].iter().sum::<f64>()
        / (frames - warmup) as f64
        // Sojourn = departure - admission; admissions are all at t=0
        // under saturation, so subtract the mean *ideal* injection time
        // instead: frame k of a rate-R pipeline would arrive at k/R.
        - (warmup..frames).map(|k| k as f64 / measured).sum::<f64>() / (frames - warmup) as f64;

    Ok(ShardSimResult {
        throughput_fps: measured,
        mean_latency_s: mean_latency.max(0.0),
        departures_s: departures,
        frames,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::interleave;
    use crate::perfmodel::link::LinkModel;

    fn run(stages: Vec<SimStage>, cut_bytes: Vec<f64>, topo: Topology) -> (f64, f64) {
        let spec = ShardSimSpec { stages, topo, cut_bytes };
        let sim = simulate_shard(&spec, 600, 100).expect("simulates");
        let predicted = interleave::steady_state_fps_on(
            &spec.topo,
            &spec.stage_rates(),
            &spec.slot_runs(),
            &spec.cut_bytes,
        );
        (sim.throughput_fps, predicted)
    }

    fn p2p(link: LinkModel) -> Topology {
        Topology::point_to_point(link)
    }

    #[test]
    fn single_stage_matches_service_rate() {
        let (sim, pred) = run(
            vec![SimStage { replicas: 1, service_s: 1e-3 }],
            vec![],
            p2p(LinkModel::default()),
        );
        assert!((sim - 1000.0).abs() / 1000.0 < 0.01, "sim {sim}");
        assert!((sim - pred).abs() / pred < 0.01);
    }

    #[test]
    fn replication_multiplies_throughput() {
        let (solo, _) = run(
            vec![SimStage { replicas: 1, service_s: 1e-3 }],
            vec![],
            p2p(LinkModel::default()),
        );
        let (trio, pred) = run(
            vec![SimStage { replicas: 3, service_s: 1e-3 }],
            vec![],
            p2p(LinkModel::default()),
        );
        assert!((trio / solo - 3.0).abs() < 0.1, "trio {trio} solo {solo}");
        assert!((trio - pred).abs() / pred < 0.02);
    }

    #[test]
    fn slowest_stage_governs_a_chain() {
        let (sim, pred) = run(
            vec![
                SimStage { replicas: 1, service_s: 0.5e-3 },
                SimStage { replicas: 1, service_s: 2e-3 },
                SimStage { replicas: 1, service_s: 1e-3 },
            ],
            vec![1e3, 1e3],
            p2p(LinkModel::default()),
        );
        assert!((sim - 500.0).abs() / 500.0 < 0.02, "sim {sim}");
        assert!((sim - pred).abs() / pred < 0.02);
    }

    #[test]
    fn replicated_hot_stage_stops_governing() {
        // 2x the hot stage: the chain speeds up to the next binding
        // constraint, exactly as the analytic model predicts.
        let (sim, pred) = run(
            vec![
                SimStage { replicas: 1, service_s: 1e-3 },
                SimStage { replicas: 2, service_s: 2e-3 },
            ],
            vec![1e3],
            p2p(LinkModel::default()),
        );
        assert!((sim - 1000.0).abs() / 1000.0 < 0.02, "sim {sim}");
        assert!((sim - pred).abs() / pred < 0.02);
    }

    #[test]
    fn narrow_fan_in_limits_the_cut() {
        // 2 fast producers, 1 fast consumer, heavy tensor: the single
        // ingress link serializes everything.
        let link = LinkModel::new(0.001, 1e-6); // 1 MB/s
        let bytes = 1e3; // 1 KB -> 1000 fps per link
        let (sim, pred) = run(
            vec![
                SimStage { replicas: 2, service_s: 1e-4 },
                SimStage { replicas: 1, service_s: 1e-4 },
            ],
            vec![bytes],
            p2p(link),
        );
        assert!((pred - 1000.0).abs() < 1e-6, "pred {pred}");
        assert!((sim - pred).abs() / pred < 0.05, "sim {sim} pred {pred}");
    }

    #[test]
    fn wide_fan_scales_the_cut() {
        let link = LinkModel::new(0.001, 1e-6);
        let bytes = 1e3;
        let (sim, pred) = run(
            vec![
                SimStage { replicas: 2, service_s: 1e-4 },
                SimStage { replicas: 2, service_s: 1e-4 },
            ],
            vec![bytes],
            p2p(link),
        );
        assert!((pred - 2000.0).abs() < 1e-6, "pred {pred}");
        assert!((sim - pred).abs() / pred < 0.05, "sim {sim} pred {pred}");
    }

    #[test]
    fn ring_boundary_serializes_a_wide_fan() {
        // The same 2->2 fan that gets 2 lanes on p2p collapses to the
        // single boundary segment on a ring — half the cut ceiling.
        let link = LinkModel::new(0.001, 1e-6);
        let bytes = 1e3;
        let (sim, pred) = run(
            vec![
                SimStage { replicas: 2, service_s: 1e-4 },
                SimStage { replicas: 2, service_s: 1e-4 },
            ],
            vec![bytes],
            Topology::ring(link),
        );
        assert!((pred - 1000.0).abs() < 1e-6, "pred {pred}");
        assert!((sim - pred).abs() / pred < 0.05, "sim {sim} pred {pred}");
    }

    #[test]
    fn star_switch_caps_concurrent_cuts_jointly() {
        // Two cuts of 1 KB each through a 1 MB/s switch with fast
        // uplinks: each cut alone could do 1e4 fps on its uplinks, but
        // the shared switch sustains only 1e6 / 2e3 = 500 fps.
        let link = LinkModel::new(0.01, 1e-6); // 10 MB/s uplinks
        let topo = Topology::star(link, 0.001); // 1 MB/s bisection
        let (sim, pred) = run(
            vec![
                SimStage { replicas: 1, service_s: 1e-4 },
                SimStage { replicas: 1, service_s: 1e-4 },
                SimStage { replicas: 1, service_s: 1e-4 },
            ],
            vec![1e3, 1e3],
            topo,
        );
        assert!((pred - 500.0).abs() < 1e-6, "pred {pred}");
        assert!((sim - pred).abs() / pred < 0.05, "sim {sim} pred {pred}");
    }

    #[test]
    fn departures_are_in_order_and_conserved() {
        let spec = ShardSimSpec {
            stages: vec![
                SimStage { replicas: 3, service_s: 1e-3 },
                SimStage { replicas: 2, service_s: 0.7e-3 },
            ],
            topo: p2p(LinkModel::default()),
            cut_bytes: vec![4e4],
        };
        let sim = simulate_shard(&spec, 200, 20).expect("simulates");
        assert_eq!(sim.frames, 200);
        assert_eq!(sim.departures_s.len(), 200);
        for w in sim.departures_s.windows(2) {
            assert!(w[1] >= w[0], "departures must be non-decreasing");
        }
        assert!(sim.mean_latency_s >= 0.0);
    }

    #[test]
    fn rejects_degenerate_specs() {
        let topo = p2p(LinkModel::default());
        assert!(simulate_shard(
            &ShardSimSpec { stages: vec![], topo, cut_bytes: vec![] },
            100,
            10
        )
        .is_err());
        assert!(simulate_shard(
            &ShardSimSpec {
                stages: vec![SimStage { replicas: 1, service_s: 1e-3 }],
                topo,
                cut_bytes: vec![1.0],
            },
            100,
            10
        )
        .is_err());
        assert!(simulate_shard(
            &ShardSimSpec {
                stages: vec![SimStage { replicas: 0, service_s: 1e-3 }],
                topo,
                cut_bytes: vec![],
            },
            100,
            10
        )
        .is_err());
    }
}
