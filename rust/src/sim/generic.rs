//! Transaction-granular simulation of the generic structure.
//!
//! Walks the layer sequence the way the real controller would: for each
//! feature-map group (Eq. 5 partitioning) the weight groups stream
//! through the ping-pong weight buffer while the MAC array computes;
//! activation groups swap in/out of DRAM when the feature-map buffer
//! cannot hold them. Double buffering overlaps the *next* transfer with
//! the *current* compute; imperfect overlap (first group, burst
//! inefficiency) is where the simulated time exceeds Eq. 11/13.

use crate::dnn::Layer;
use crate::perfmodel::generic::{layer_latency, Dataflow, GenericConfig};
use crate::sim::dram::DramModel;
use crate::sim::trace::{EventKind, Trace};
use crate::sim::SimResult;

/// Simulate one layer on the generic structure; returns cycles for one
/// frame (weight traffic amortized over `batch`).
pub fn simulate_layer(
    l: &Layer,
    cfg: &GenericConfig,
    dram: &DramModel,
    batch: usize,
    trace: &mut Trace,
) -> f64 {
    let batch_f = batch.max(1) as f64;
    // Reuse the estimator's partitioning decisions (groups, dataflow,
    // residency) — the simulator times the schedule, it does not re-plan.
    let plan = layer_latency(l, cfg, dram.peak_bytes_per_s / 1e9, batch);

    let eff_cpf = (l.input.c as f64 / l.groups() as f64).min(cfg.cpf as f64).max(1.0);
    let eff_kpf = (l.output.c as f64).min(cfg.kpf as f64).max(1.0);
    // Integer lane quantization (the model divides real-valued).
    let c_steps = ((l.input.c as f64 / l.groups() as f64) / eff_cpf).ceil();
    let k_steps = (l.output.c as f64 / eff_kpf).ceil();
    let win = (l.kernel() * l.kernel_w()) as f64;
    let pixels = (l.output.h * l.output.w) as f64;
    let compute_cycles = pixels * win * c_steps * k_steps + 64.0; // array drain

    let w_bytes = l.weight_bytes(cfg.ww);
    let ifm_bytes = l.ifm_bytes(cfg.dw);
    let ofm_bytes = l.ofm_bytes(cfg.dw);

    let (groups_outer, w_traffic, fm_in_traffic, fm_out_traffic) = match plan.dataflow {
        Dataflow::InputStationary => {
            let g = plan.g_fm.max(1.0);
            let (fi, fo) = if plan.fm_resident { (0.0, 0.0) } else { (ifm_bytes, ofm_bytes) };
            (g, w_bytes * g / batch_f, fi, fo)
        }
        Dataflow::WeightStationary => {
            let g = plan.g_w.max(1.0);
            let (fi, fo) = if plan.fm_resident && g <= 1.0 {
                (0.0, 0.0)
            } else {
                (ifm_bytes * g, ofm_bytes * g)
            };
            (g, w_bytes / batch_f, fi, fo)
        }
    };

    // Per-group compute and transfer; double buffering overlaps them but
    // the first group's load is exposed, and each group pays burst math.
    let per_group_compute = compute_cycles / groups_outer;
    let w_cycles_group = dram.transfer_cycles(w_traffic / groups_outer, k_steps.max(1.0));
    let fm_txns = (l.input.h as f64).max(1.0); // line-based partitioning
    let fi_cycles_group = dram.transfer_cycles(fm_in_traffic / groups_outer, fm_txns);
    let fo_cycles_group = dram.transfer_cycles(fm_out_traffic / groups_outer, fm_txns);
    let mem_group = w_cycles_group + fi_cycles_group + fo_cycles_group;

    let steady = per_group_compute.max(mem_group) * (groups_outer - 1.0).max(0.0);
    let exposed = mem_group + per_group_compute; // first load + last compute
    let cycles = steady + exposed;

    if mem_group > per_group_compute {
        trace.record(cycles as u64, &l.name, EventKind::Stall, 0.0);
    }
    trace.record(
        cycles as u64,
        &l.name,
        EventKind::DramRead,
        w_traffic + fm_in_traffic,
    );
    if fm_out_traffic > 0.0 {
        trace.record(cycles as u64, &l.name, EventKind::DramWrite, fm_out_traffic);
    }
    cycles
}

/// Simulate the generic structure over a layer slice; returns the batch
/// period and derived rates.
pub fn simulate_generic(
    layers: &[&Layer],
    cfg: &GenericConfig,
    dram: &DramModel,
    batch: usize,
    trace: &mut Trace,
) -> anyhow::Result<SimResult> {
    anyhow::ensure!(!layers.is_empty(), "empty generic layer range");
    let batch_f = batch.max(1) as f64;
    let mut total_cycles = 0.0f64;
    let mut compute_cycles = 0.0f64;
    let mut dram_bytes = 0.0f64;
    for l in layers {
        let per_frame = simulate_layer(l, cfg, dram, batch, trace);
        total_cycles += per_frame * batch_f;
        let eff_cpf = (l.input.c as f64 / l.groups() as f64).min(cfg.cpf as f64).max(1.0);
        let eff_kpf = (l.output.c as f64).min(cfg.kpf as f64).max(1.0);
        compute_cycles += l.macs() as f64 / (eff_cpf * eff_kpf) * batch_f;
        dram_bytes += l.weight_bytes(cfg.ww);
    }
    let fps = batch_f / (total_cycles / dram.clock_hz);
    let ops: f64 = layers.iter().map(|l| l.ops() as f64).sum();
    Ok(SimResult {
        cycles_per_batch: total_cycles as u64,
        fps,
        gops: fps * ops / 1e9,
        dram_bytes,
        compute_utilization: (compute_cycles / total_cycles).min(1.0),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::layer::{conv_out_dim, LayerKind, TensorShape};
    use crate::dnn::Precision;
    use crate::perfmodel::generic::{estimate, BufferStrategy};

    fn conv_layer(c: usize, hw: usize, k: usize, kern: usize) -> Layer {
        let input = TensorShape::new(c, hw, hw);
        let o = conv_out_dim(hw, kern, 1, kern / 2);
        Layer {
            name: "t".into(),
            kind: LayerKind::Conv {
                kernel: kern,
                kernel_w: kern,
                stride: 1,
                pad: kern / 2,
                groups: 1,
            },
            input,
            output: TensorShape::new(k, o, o),
            precision: Precision::Int16,
        }
    }

    fn cfg() -> GenericConfig {
        GenericConfig::with_budget(
            32,
            64,
            Precision::Int16,
            Precision::Int16,
            BufferStrategy::FmAccumInBram,
            200.0,
            1500.0,
        )
    }

    #[test]
    fn simulated_close_to_analytical() {
        // Fig. 8 premise: generic model error ~2% vs measurement.
        let layers = [
            conv_layer(64, 112, 64, 3),
            conv_layer(128, 56, 128, 3),
            conv_layer(256, 56, 256, 1),
        ];
        let refs: Vec<&Layer> = layers.iter().collect();
        let c = cfg();
        let dram = DramModel::new(19.2, 200.0);
        let est = estimate(&refs, &c, 19.2, 1);
        let sim = simulate_generic(&refs, &c, &dram, 1, &mut Trace::disabled()).unwrap();
        let err = (est.throughput_fps - sim.fps).abs() / sim.fps;
        assert!(err < 0.2, "err {err} est {} sim {}", est.throughput_fps, sim.fps);
    }

    #[test]
    fn sim_slower_than_pure_compute_bound() {
        let layers = [conv_layer(256, 56, 256, 3)];
        let refs: Vec<&Layer> = layers.iter().collect();
        let c = cfg();
        let dram = DramModel::new(19.2, 200.0);
        let sim = simulate_generic(&refs, &c, &dram, 1, &mut Trace::disabled()).unwrap();
        let ideal = layers[0].macs() as f64 / (32.0 * 64.0) / 200e6;
        assert!(1.0 / sim.fps >= ideal);
    }

    #[test]
    fn batch_improves_weight_bound_layers() {
        let layers = [conv_layer(512, 7, 512, 3)];
        let refs: Vec<&Layer> = layers.iter().collect();
        let c = cfg();
        let dram = DramModel::new(2.0, 200.0);
        let b1 = simulate_generic(&refs, &c, &dram, 1, &mut Trace::disabled()).unwrap();
        let b8 = simulate_generic(&refs, &c, &dram, 8, &mut Trace::disabled()).unwrap();
        assert!(b8.fps > b1.fps, "b8 {} b1 {}", b8.fps, b1.fps);
    }

    #[test]
    fn utilization_bounded() {
        let layers = [conv_layer(64, 56, 64, 3), conv_layer(64, 56, 128, 3)];
        let refs: Vec<&Layer> = layers.iter().collect();
        let c = cfg();
        let dram = DramModel::new(19.2, 200.0);
        let sim = simulate_generic(&refs, &c, &dram, 1, &mut Trace::disabled()).unwrap();
        assert!(sim.compute_utilization > 0.0 && sim.compute_utilization <= 1.0);
    }
}
