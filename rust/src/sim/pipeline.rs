//! Column-granular simulation of the pipeline structure.
//!
//! Each stage is walked column-by-column (the DNNBuilder fine-grained
//! pipeline): a stage may compute output column `j` once its column/row
//! buffer holds input columns `j..j+S`. Weight tiles stream from DRAM
//! through a ping-pong buffer; a stage stalls when its next weight group
//! has not landed. The steady-state batch period is the slowest stage's
//! simulated interval including those stalls — the quantity the
//! analytical model (Eq. 3–4) approximates.

use crate::dnn::Layer;
use crate::perfmodel::pipeline::PipelineConfig;
use crate::sim::dram::DramModel;
use crate::sim::trace::{EventKind, Trace};
use crate::sim::SimResult;

/// Simulate the pipeline structure over `layers` with config `cfg`.
///
/// `dram` must already be scaled to the pipeline's bandwidth share.
pub fn simulate_pipeline(
    layers: &[&Layer],
    cfg: &PipelineConfig,
    dram: &DramModel,
    trace: &mut Trace,
) -> anyhow::Result<SimResult> {
    anyhow::ensure!(layers.len() == cfg.stages.len(), "stage/layer count mismatch");
    anyhow::ensure!(!layers.is_empty(), "empty pipeline");
    let batch = cfg.batch.max(1) as f64;

    // Traffic split mirrors the estimator: input stream + per-stage weights.
    let input_bytes = layers[0].ifm_bytes(cfg.stages[0].dw) * batch;
    let weight_bytes: Vec<f64> = layers
        .iter()
        .zip(&cfg.stages)
        .map(|(l, s)| l.weight_bytes(s.ww))
        .collect();
    let total_traffic = input_bytes + weight_bytes.iter().sum::<f64>();

    let mut worst_cycles = 0.0f64;
    let mut sum_compute = 0.0f64;
    let mut dram_bytes = 0.0f64;

    for (i, (l, s)) in layers.iter().zip(&cfg.stages).enumerate() {
        // --- compute, column by column ---
        let out_w = l.output.w.max(1) as u64;
        let out_h = l.output.h.max(1) as u64;
        // MACs per output column, integer-quantized over the lanes:
        // ceil(C/g / CPF) · ceil(K / KPF) vector steps per pixel.
        let c_steps = ((l.input.c / l.groups()) as f64 / s.cpf as f64).ceil().max(1.0);
        let k_steps = (l.output.c as f64 / s.kpf as f64).ceil().max(1.0);
        let win = (l.kernel() * l.kernel_w()) as f64;
        let cycles_per_pixel = c_steps * k_steps * win;
        // +1 cycle/column pipeline restart (line-buffer rotate).
        let cycles_per_col = cycles_per_pixel * out_h as f64 + 1.0;
        let compute_cycles = cycles_per_col * out_w as f64;

        // --- weights, streamed as contiguous DMA chunks through the
        // ping-pong buffer (64 KiB descriptors, the typical AXI-DMA
        // configuration) ---
        let dma_txns = (weight_bytes[i] / 65536.0).ceil().max(1.0);
        let share = if total_traffic > 0.0 {
            (weight_bytes[i] / total_traffic).max(1e-9)
        } else {
            1.0
        };
        let stage_dram = dram.with_bandwidth_share(dram.peak_bytes_per_s / 1e9 * share);
        let weight_cycles = stage_dram.transfer_cycles(weight_bytes[i], dma_txns);
        dram_bytes += weight_bytes[i];

        // Steady state: compute for the whole batch overlaps the batch's
        // single weight refresh; a refresh slower than compute stalls.
        let interval = (compute_cycles * batch).max(weight_cycles);
        if weight_cycles > compute_cycles * batch {
            trace.record(interval as u64, &l.name, EventKind::Stall, 0.0);
        }
        trace.record(compute_cycles as u64, &l.name, EventKind::ComputeEnd, 0.0);
        trace.record(weight_cycles as u64, &l.name, EventKind::DramRead, weight_bytes[i]);

        sum_compute += compute_cycles * batch;
        worst_cycles = worst_cycles.max(interval);
    }

    // Input stream constraint. Frames arrive as contiguous DMA bursts
    // (the capture pipeline writes them sequentially), not column
    // transactions — the column walk happens out of the on-chip buffer.
    let in_share = if total_traffic > 0.0 { input_bytes / total_traffic } else { 1.0 };
    let in_dram = dram.with_bandwidth_share(dram.peak_bytes_per_s / 1e9 * in_share.max(1e-9));
    let in_txns = (input_bytes / 65536.0).ceil().max(batch);
    let in_cycles = in_dram.transfer_cycles(input_bytes, in_txns);
    dram_bytes += input_bytes;
    worst_cycles = worst_cycles.max(in_cycles);
    trace.record(in_cycles as u64, "input", EventKind::DramRead, input_bytes);

    let fps = batch / (worst_cycles / dram.clock_hz);
    let ops: f64 = layers.iter().map(|l| l.ops() as f64).sum();
    Ok(SimResult {
        cycles_per_batch: worst_cycles as u64,
        fps,
        gops: fps * ops / 1e9,
        dram_bytes,
        compute_utilization: (sum_compute / (worst_cycles * layers.len() as f64)).min(1.0),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::zoo;
    use crate::dnn::{Precision, TensorShape};
    use crate::dse::local_pipeline;
    use crate::fpga::{FpgaDevice, ResourceBudget};

    fn setup(h: usize, w: usize, sp: usize) -> (Vec<crate::dnn::Layer>, PipelineConfig) {
        let layers: Vec<crate::dnn::Layer> =
            zoo::vgg16_conv(TensorShape::new(3, h, w), Precision::Int16)
                .layers
                .into_iter()
                .filter(|l| l.is_compute())
                .take(sp)
                .collect();
        let refs: Vec<&crate::dnn::Layer> = layers.iter().collect();
        let d = FpgaDevice::ku115();
        let budget = ResourceBudget::fraction_of(&d, 0.6, 0.6, 0.7);
        let plan = local_pipeline::optimize(&refs, &budget, 1, 200.0, Precision::Int16, Precision::Int16)
            .unwrap();
        (layers, plan.config)
    }

    #[test]
    fn simulated_close_to_analytical() {
        // Fig. 7 premise: the analytical model is within a few percent of
        // "measurement" (our simulator).
        let (layers, cfg) = setup(224, 224, 8);
        let refs: Vec<&crate::dnn::Layer> = layers.iter().collect();
        let d = FpgaDevice::ku115();
        let bw = d.bandwidth_gbps * 0.7;
        let est = crate::perfmodel::pipeline::estimate(&refs, &cfg, bw).unwrap();
        let dram = DramModel::new(bw, 200.0);
        let sim = simulate_pipeline(&refs, &cfg, &dram, &mut Trace::disabled()).unwrap();
        let err = (est.throughput_fps - sim.fps).abs() / sim.fps;
        assert!(err < 0.15, "estimation error {err} (est {} sim {})", est.throughput_fps, sim.fps);
    }

    #[test]
    fn sim_never_beats_ideal() {
        // Burst overheads and integer quantization only slow things down
        // relative to the ideal Eq.3 compute bound.
        let (layers, cfg) = setup(224, 224, 6);
        let refs: Vec<&crate::dnn::Layer> = layers.iter().collect();
        let ideal_worst = refs
            .iter()
            .zip(&cfg.stages)
            .map(|(l, s)| l.macs() as f64 / (s.pf() as f64 * 200e6))
            .fold(0.0f64, f64::max);
        let dram = DramModel::new(19.2, 200.0);
        let sim = simulate_pipeline(&refs, &cfg, &dram, &mut Trace::disabled()).unwrap();
        assert!(1.0 / sim.fps >= ideal_worst * 0.999);
    }

    #[test]
    fn empty_pipeline_errors() {
        let dram = DramModel::new(19.2, 200.0);
        let cfg = PipelineConfig { stages: vec![], batch: 1, freq_mhz: 200.0 };
        assert!(simulate_pipeline(&[], &cfg, &dram, &mut Trace::disabled()).is_err());
    }

    #[test]
    fn trace_records_events() {
        let (layers, cfg) = setup(64, 64, 4);
        let refs: Vec<&crate::dnn::Layer> = layers.iter().collect();
        let dram = DramModel::new(19.2, 200.0);
        let mut trace = Trace::enabled(1024);
        simulate_pipeline(&refs, &cfg, &dram, &mut trace).unwrap();
        assert!(trace.dram_bytes() > 0.0);
        assert!(!trace.events.is_empty());
    }
}
