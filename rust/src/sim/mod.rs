//! Cycle-approximate accelerator simulator — the board-level-measurement
//! substitute (DESIGN.md, hardware substitution).
//!
//! The simulator executes the same accelerator configurations the
//! analytical models estimate ([`crate::perfmodel`]), but at DRAM-
//! transaction and column granularity, with the second-order effects a
//! real board shows and a closed-form model ignores:
//!
//! * DRAM burst efficiency: short transfers waste activate/precharge
//!   cycles ([`dram::DramModel`]).
//! * Pipeline fill/drain: column-granular stage start-up.
//! * Ping-pong buffer stalls when a weight group arrives late.
//! * Integer quantization of loop trip counts (ceil effects the models
//!   round away).
//!
//! Fig. 7 / Fig. 8 compare analytical estimates against this simulator,
//! reproducing the paper's estimation-error experiments. [`shard`]
//! extends the family across boards: a discrete-event walk of a
//! replicated, frame-interleaved shard plan (per-replica servers,
//! per-board links, in-order departures) that `tests/sim_vs_model.rs`
//! differences against [`crate::perfmodel::interleave`] and the live
//! [`crate::coordinator::ShardedPipeline`].

pub mod dram;
pub mod generic;
pub mod hybrid;
pub mod pipeline;
pub mod shard;
pub mod trace;

pub use dram::DramModel;
pub use generic::simulate_generic;
pub use hybrid::simulate_candidate;
pub use pipeline::simulate_pipeline;
pub use shard::{simulate_shard, ShardSimResult, ShardSimSpec, SimStage};


/// Measured (simulated) performance of an accelerator run.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Cycles for one steady-state batch period.
    pub cycles_per_batch: u64,
    /// Frames per second at the configured clock.
    pub fps: f64,
    /// Sustained GOP/s.
    pub gops: f64,
    /// Total DRAM bytes moved per batch.
    pub dram_bytes: f64,
    /// Fraction of cycles the compute fabric was busy.
    pub compute_utilization: f64,
}
