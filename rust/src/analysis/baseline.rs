//! Lint baselines: grandfather pre-existing findings so the `--deny`
//! gate can be adopted before every historical site is fixed.
//!
//! A baseline waives up to `count` findings of one rule in one file —
//! deliberately coarse (no line numbers), so unrelated edits that shift
//! lines don't churn the file, while any *new* finding in a baselined
//! file still trips the gate once the per-file budget is spent. The
//! repo's shipped `lint-baseline.json` is empty: the tree is clean, and
//! the file exists to document the format and keep the CI wiring
//! honest.

use std::collections::HashMap;

use crate::util::json::Json;

use super::{Finding, RuleId};

/// Waived finding counts, keyed by `(rule, file)`.
#[derive(Debug, Default)]
pub struct Baseline {
    entries: HashMap<(RuleId, String), usize>,
}

impl Baseline {
    /// A baseline that waives nothing.
    pub fn empty() -> Baseline {
        Baseline::default()
    }

    /// Parse the JSON baseline format:
    /// `{"version":1,"entries":[{"rule":"L005","file":"src/x.rs","count":2}]}`.
    pub fn parse(text: &str) -> anyhow::Result<Baseline> {
        let doc = Json::parse(text)?;
        let version = doc.get("version").and_then(Json::as_f64).unwrap_or(0.0) as i64;
        anyhow::ensure!(version == 1, "unsupported baseline version {version} (want 1)");
        let entries = doc
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("baseline: missing `entries` array"))?;
        let mut map = HashMap::new();
        for (i, e) in entries.iter().enumerate() {
            let rule_str = e
                .get("rule")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("baseline entry {i}: missing `rule`"))?;
            let rule = RuleId::parse(rule_str)
                .ok_or_else(|| anyhow::anyhow!("baseline entry {i}: unknown rule {rule_str}"))?;
            let file = e
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("baseline entry {i}: missing `file`"))?;
            let count = e.get("count").and_then(Json::as_f64).unwrap_or(1.0);
            anyhow::ensure!(count >= 1.0, "baseline entry {i}: count must be >= 1");
            *map.entry((rule, file.to_string())).or_insert(0) += count as usize;
        }
        Ok(Baseline { entries: map })
    }

    /// Load a baseline file from disk.
    pub fn load(path: &std::path::Path) -> anyhow::Result<Baseline> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read baseline {}: {e}", path.display()))?;
        Baseline::parse(&text)
    }

    /// Render findings as a baseline document (for `--write-baseline`).
    pub fn render(findings: &[Finding]) -> String {
        let mut counts: HashMap<(RuleId, &str), usize> = HashMap::new();
        for f in findings {
            *counts.entry((f.rule, f.file.as_str())).or_insert(0) += 1;
        }
        let mut keys: Vec<_> = counts.keys().cloned().collect();
        keys.sort();
        let entries: Vec<Json> = keys
            .into_iter()
            .map(|(rule, file)| {
                let count = counts[&(rule, file)];
                Json::obj(vec![
                    ("rule", Json::s(rule.code())),
                    ("file", Json::s(file)),
                    ("count", Json::n(count as f64)),
                ])
            })
            .collect();
        Json::obj(vec![("version", Json::n(1.0)), ("entries", Json::Arr(entries))]).render()
    }

    /// Split findings into `(fresh, suppressed_count)`: per `(rule,
    /// file)`, the first `count` findings in order are suppressed, the
    /// rest stay live.
    pub fn apply(&self, findings: Vec<Finding>) -> (Vec<Finding>, usize) {
        let mut budget: HashMap<(RuleId, &str), usize> = HashMap::new();
        for ((rule, file), count) in &self.entries {
            budget.insert((*rule, file.as_str()), *count);
        }
        let mut fresh = Vec::new();
        let mut suppressed = 0usize;
        for f in findings {
            let spent = match budget.get_mut(&(f.rule, f.file.as_str())) {
                Some(left) if *left > 0 => {
                    *left -= 1;
                    true
                }
                _ => false,
            };
            if spent {
                suppressed += 1;
            } else {
                fresh.push(f);
            }
        }
        (fresh, suppressed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn f(rule: RuleId, file: &str, line: u32) -> Finding {
        Finding { rule, file: file.to_string(), line, message: String::new() }
    }

    #[test]
    fn round_trip_and_apply() {
        let findings = vec![
            f(RuleId::L005, "src/a.rs", 3),
            f(RuleId::L005, "src/a.rs", 9),
            f(RuleId::L007, "src/b.rs", 1),
        ];
        let doc = Baseline::render(&findings);
        let base = Baseline::parse(&doc).expect("baseline parses");
        let (fresh, suppressed) = base.apply(findings.clone());
        assert!(fresh.is_empty(), "{fresh:?}");
        assert_eq!(suppressed, 3);

        // A new finding beyond the budget stays live.
        let mut more = findings;
        more.push(f(RuleId::L005, "src/a.rs", 20));
        let (fresh, suppressed) = base.apply(more);
        assert_eq!(suppressed, 3);
        assert_eq!(fresh.len(), 1);
        assert_eq!(fresh[0].line, 20);
    }

    #[test]
    fn empty_baseline_waives_nothing() {
        let (fresh, suppressed) =
            Baseline::empty().apply(vec![f(RuleId::L001, "src/a.rs", 1)]);
        assert_eq!(fresh.len(), 1);
        assert_eq!(suppressed, 0);
    }

    #[test]
    fn rejects_bad_documents() {
        assert!(Baseline::parse("{}").is_err());
        assert!(Baseline::parse(r#"{"version":2,"entries":[]}"#).is_err());
        let unknown = r#"{"version":1,"entries":[{"rule":"L999","file":"x","count":1}]}"#;
        assert!(Baseline::parse(unknown).is_err());
    }

    #[test]
    fn empty_entries_document_parses() {
        let base = Baseline::parse(r#"{"version":1,"entries":[]}"#).expect("parses");
        let (fresh, _) = base.apply(vec![f(RuleId::L006, "src/c.rs", 2)]);
        assert_eq!(fresh.len(), 1);
    }
}
