//! The lint rules: token-pattern checks, one per historical bug class.
//!
//! All rules are intraprocedural — they look at one function body (or
//! one token window) at a time and do not follow calls. That blindness
//! is deliberate: every one of the seed bugs was visible within a
//! single function, and an intraprocedural check has a false-positive
//! rate low enough to run under `--deny`. Where a heuristic needs
//! scoping to stay quiet (L003/L005 apply only under `coordinator/`,
//! L002/L006 exempt their blessed helper files), the scoping is part of
//! the rule and documented on it.
//!
//! Findings in `#[cfg(test)]` regions and on allow-annotated lines are
//! filtered by the caller ([`super::analyze_source`]); rules just
//! report every raw match.

use super::lexer::{is_float_literal, Tok, TokKind};
use super::{matching, FileContext, Finding, RuleId};

/// Method/function names treated as potentially blocking for L001.
/// `Condvar::wait` is deliberately absent: waiting on a condvar
/// *releases* the mutex, which is the fix for a convoy, not the bug.
const BLOCKING: &[&str] = &[
    "recv",
    "recv_timeout",
    "recv_deadline",
    "send",
    "join",
    "sleep",
    "accept",
    "connect",
    "read",
    "read_exact",
    "read_to_end",
    "read_to_string",
    "write",
    "write_all",
    "flush",
    "park",
    "park_timeout",
];

/// `Metrics` counter fields whose raw mutation L002 flags.
const COUNTER_FIELDS: &[&str] =
    &["requests", "batches", "frames", "ok_frames", "errors", "shed", "timed_out"];

/// Atomic mutators that count as writes for L002.
const COUNTER_MUTATORS: &[&str] = &["fetch_add", "fetch_sub", "store"];

/// Collection-growing calls L003 looks for inside loops.
const GROWTH_CALLS: &[&str] = &["push", "push_back", "push_front", "insert"];

/// Identifier substrings that count as capping/sweeping evidence for
/// L003: if the enclosing function mentions any of these, growth is
/// assumed bounded.
const CAP_HINTS: &[&str] = &[
    "pop", "remove", "clear", "drain", "retain", "truncate", "sweep", "evict", "take",
    "split_off", "dedup", "shrink",
];

/// Calls that *obtain* a socket, putting the function in scope for L004.
const SOCKET_OBTAIN: &[&str] = &["accept", "incoming", "connect", "bind"];

/// Raw I/O calls L004 treats as hang-prone without a timeout.
const SOCKET_IO: &[&str] =
    &["read", "read_exact", "read_to_end", "read_to_string", "write", "write_all", "flush"];

/// Calls that draw from host entropy, which L009 flags in deterministic
/// scopes.
const ENTROPY_CALLS: &[&str] = &["thread_rng", "from_entropy", "random"];

/// Run one rule over one file.
pub fn run(rule: RuleId, ctx: &FileContext) -> Vec<Finding> {
    match rule {
        RuleId::L001 => l001_guard_across_blocking(ctx),
        RuleId::L002 => l002_counter_outside_helpers(ctx),
        RuleId::L003 => l003_unbounded_loop_growth(ctx),
        RuleId::L004 => l004_socket_without_timeout(ctx),
        RuleId::L005 => l005_unwrap_on_serving_path(ctx),
        RuleId::L006 => l006_float_equality(ctx),
        RuleId::L007 => l007_unnamed_thread(ctx),
        RuleId::L008 => l008_wall_clock_on_serving_path(ctx),
        RuleId::L009 => l009_unseeded_randomness(ctx),
    }
}

fn finding(ctx: &FileContext, rule: RuleId, line: u32, message: String) -> Finding {
    Finding { rule, file: ctx.path.clone(), line, message }
}

/// `name` called as a method or path fn: `.name(` or `::name(`.
fn is_call_of(code: &[Tok], i: usize, names: &[&str]) -> bool {
    code[i].kind == TokKind::Ident
        && names.contains(&code[i].text.as_str())
        && i > 0
        && (code[i - 1].is_punct(".") || code[i - 1].is_punct("::"))
        && matches!(code.get(i + 1), Some(t) if t.is_punct("("))
}

/// Token index ranges `(open_brace, close_brace)` of every `fn` body.
/// Nested fns yield nested ranges; the caller's per-line dedup absorbs
/// any double reporting.
fn fn_bodies(code: &[Tok]) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < code.len() {
        if code[i].is_ident("fn") {
            let mut j = i + 1;
            while j < code.len() && !code[j].is_punct("{") && !code[j].is_punct(";") {
                j += 1;
            }
            if j < code.len() && code[j].is_punct("{") {
                if let Some(close) = matching(code, j, "{", "}") {
                    out.push((j, close));
                }
            }
            i = j + 1;
            continue;
        }
        i += 1;
    }
    out
}

/// L001 — a `MutexGuard` bound by `let ... = ....lock(...)` is still
/// live when a blocking call runs (PR 2: the admission lock was held
/// across `respond.send`, convoying every submitter behind one slow
/// receiver). Tracks guard bindings per brace depth, releases them on
/// `drop(name)` or scope exit, and understands that the scrutinee
/// temporary of `if let`/`while let` lives for the whole block.
fn l001_guard_across_blocking(ctx: &FileContext) -> Vec<Finding> {
    let code = &ctx.code;
    let mut out = Vec::new();
    for &(open, close) in &fn_bodies(code) {
        // Live guards: (binding name, brace depth, lock line).
        let mut guards: Vec<(String, i32, u32)> = Vec::new();
        // Guards that become live once their `let` statement ends:
        // (first token index past the statement, guard).
        let mut pending: Vec<(usize, (String, i32, u32))> = Vec::new();
        let mut depth = 0i32;
        let mut i = open + 1;
        while i < close {
            let mut k = 0;
            while k < pending.len() {
                if pending[k].0 == i {
                    guards.push(pending.remove(k).1);
                } else {
                    k += 1;
                }
            }
            let t = &code[i];
            if t.is_punct("{") {
                depth += 1;
            } else if t.is_punct("}") {
                depth -= 1;
                guards.retain(|g| g.1 <= depth);
            } else if t.is_ident("let") {
                let conditional =
                    i > open && (code[i - 1].is_ident("if") || code[i - 1].is_ident("while"));
                if conditional {
                    // `if let` / `while let`: a `.lock(` in the
                    // scrutinee produces a temporary guard that lives
                    // for the whole block (the classic temporary-
                    // lifetime extension gotcha).
                    let mut d = 0i32;
                    let mut lock_line = None;
                    let mut j = i + 1;
                    while j < close {
                        let u = &code[j];
                        if u.is_punct("{") && d == 0 {
                            break;
                        }
                        if u.is_punct("(") || u.is_punct("[") || u.is_punct("{") {
                            d += 1;
                        } else if u.is_punct(")") || u.is_punct("]") || u.is_punct("}") {
                            d -= 1;
                        } else if is_call_of(code, j, &["lock"]) {
                            lock_line = Some(u.line);
                        }
                        j += 1;
                    }
                    if let Some(line) = lock_line {
                        if j < close {
                            let g = ("<scrutinee temporary>".to_string(), depth + 1, line);
                            pending.push((j, g));
                        }
                    }
                } else {
                    // Plain `let`: scan the statement. Within it, a
                    // blocking call after `.lock(` is already a convoy
                    // (`q.lock().unwrap().rx.recv()`); after it, the
                    // binding becomes a live guard.
                    let mut name = String::new();
                    let mut j = i + 1;
                    if j < close && code[j].is_ident("mut") {
                        j += 1;
                    }
                    if j < close && code[j].kind == TokKind::Ident {
                        name = code[j].text.clone();
                    }
                    let mut d = 0i32;
                    let mut lock_line = None;
                    let mut k = i + 1;
                    let stmt_end = loop {
                        if k >= close {
                            break close;
                        }
                        let u = &code[k];
                        if u.is_punct(";") && d == 0 {
                            break k;
                        }
                        if u.is_punct("{") || u.is_punct("[") {
                            d += 1;
                        } else if u.is_punct("}") || u.is_punct("]") {
                            if d == 0 {
                                break k;
                            }
                            d -= 1;
                        } else if is_call_of(code, k, &["lock"]) {
                            lock_line = Some(u.line);
                        } else if lock_line.is_some() && is_call_of(code, k, BLOCKING) {
                            out.push(finding(
                                ctx,
                                RuleId::L001,
                                u.line,
                                format!(
                                    "`{}()` may block while this statement's `.lock(` guard \
                                     is live (PR 2 convoy); split the statement and drop first",
                                    u.text
                                ),
                            ));
                        }
                        k += 1;
                    };
                    if let Some(line) = lock_line {
                        let g = if name.is_empty() { "<unnamed>".to_string() } else { name };
                        pending.push((stmt_end + 1, (g, depth, line)));
                    }
                }
            } else if t.is_ident("drop")
                && matches!(code.get(i + 1), Some(u) if u.is_punct("("))
                && matches!(code.get(i + 3), Some(u) if u.is_punct(")"))
            {
                if let Some(arg) = code.get(i + 2) {
                    if arg.kind == TokKind::Ident {
                        guards.retain(|g| g.0 != arg.text);
                    }
                }
            } else if is_call_of(code, i, BLOCKING) {
                if let Some(g) = guards.last() {
                    out.push(finding(
                        ctx,
                        RuleId::L001,
                        t.line,
                        format!(
                            "`{}()` may block while guard `{}` (locked on line {}) is held \
                             (PR 2 convoy); drop the guard or bound the wait",
                            t.text, g.0, g.2
                        ),
                    ));
                }
            }
            i += 1;
        }
    }
    out
}

/// L002 — a `Metrics` counter field mutated outside `metrics.rs` /
/// `quota.rs` helpers (PR 6: sibling failover bumped `requests` at two
/// call sites and double-counted; the reconciliation identity
/// `requests == ok_frames + errors + shed` only holds when every bump
/// goes through one audited helper).
fn l002_counter_outside_helpers(ctx: &FileContext) -> Vec<Finding> {
    if ctx.file_name == "metrics.rs" || ctx.file_name == "quota.rs" {
        return Vec::new();
    }
    let code = &ctx.code;
    let mut out = Vec::new();
    for (i, t) in code.iter().enumerate() {
        if t.kind == TokKind::Ident
            && COUNTER_FIELDS.contains(&t.text.as_str())
            && i > 0
            && code[i - 1].is_punct(".")
            && matches!(code.get(i + 1), Some(u) if u.is_punct("."))
            && matches!(code.get(i + 2),
                Some(u) if u.kind == TokKind::Ident
                    && COUNTER_MUTATORS.contains(&u.text.as_str()))
            && matches!(code.get(i + 3), Some(u) if u.is_punct("("))
        {
            out.push(finding(
                ctx,
                RuleId::L002,
                t.line,
                format!(
                    "raw `{}.{}` outside metrics.rs helpers; route it through a \
                     `Metrics::record_*` method (PR 6 double-count)",
                    t.text,
                    code[i + 2].text
                ),
            ));
        }
    }
    out
}

/// L003 — `push`/`insert` into a collection inside a `loop`/`while`
/// body, in a function with no capping evidence (PR 6: the EDF slack
/// index grew one entry per admission and was never swept). Scoped to
/// `coordinator/` paths — that is where long-lived worker loops live;
/// parser loops elsewhere grow their output by design. `for` loops are
/// exempt: they are bounded by their iterator.
fn l003_unbounded_loop_growth(ctx: &FileContext) -> Vec<Finding> {
    if !ctx.path.contains("coordinator") {
        return Vec::new();
    }
    let code = &ctx.code;
    let mut out = Vec::new();
    for &(open, close) in &fn_bodies(code) {
        let capped = code[open..=close].iter().any(|t| {
            t.kind == TokKind::Ident && CAP_HINTS.iter().any(|h| t.text.contains(h))
        });
        if capped {
            continue;
        }
        // Collect `loop`/`while` body spans, then flag growth inside.
        let mut spans: Vec<(usize, usize)> = Vec::new();
        let mut i = open + 1;
        while i < close {
            if code[i].is_ident("loop")
                && matches!(code.get(i + 1), Some(t) if t.is_punct("{"))
            {
                if let Some(c) = matching(code, i + 1, "{", "}") {
                    spans.push((i + 1, c));
                }
            } else if code[i].is_ident("while") {
                let mut d = 0i32;
                let mut j = i + 1;
                while j < close {
                    if code[j].is_punct("{") && d == 0 {
                        break;
                    }
                    if code[j].is_punct("(") || code[j].is_punct("[") || code[j].is_punct("{") {
                        d += 1;
                    } else if code[j].is_punct(")")
                        || code[j].is_punct("]")
                        || code[j].is_punct("}")
                    {
                        d -= 1;
                    }
                    j += 1;
                }
                if j < close {
                    if let Some(c) = matching(code, j, "{", "}") {
                        spans.push((j, c));
                    }
                }
            }
            i += 1;
        }
        for k in open + 1..close {
            if is_call_of(code, k, GROWTH_CALLS)
                && spans.iter().any(|&(a, b)| k > a && k < b)
            {
                out.push(finding(
                    ctx,
                    RuleId::L003,
                    code[k].line,
                    format!(
                        "`{}` grows a collection inside a worker loop and this fn never \
                         pops/sweeps/evicts (PR 6 EDF slack leak); cap it or sweep it",
                        code[k].text
                    ),
                ));
            }
        }
    }
    out
}

/// L004 — a function that *obtains* a socket (`accept`, `incoming`,
/// `connect`, `bind`) and then does raw `read*`/`write*` I/O without
/// ever calling `set_read_timeout`/`set_write_timeout` (PR 6: a stalled
/// scrape client hung the metrics exporter forever). One finding per
/// function, on the first I/O call.
fn l004_socket_without_timeout(ctx: &FileContext) -> Vec<Finding> {
    let code = &ctx.code;
    let mut out = Vec::new();
    for &(open, close) in &fn_bodies(code) {
        let body = open + 1..close;
        let obtains = body.clone().any(|k| is_call_of(code, k, SOCKET_OBTAIN));
        if !obtains {
            continue;
        }
        let sets_timeout = body.clone().any(|k| {
            code[k].is_ident("set_read_timeout") || code[k].is_ident("set_write_timeout")
        });
        if sets_timeout {
            continue;
        }
        if let Some(k) = body.clone().find(|&k| is_call_of(code, k, SOCKET_IO)) {
            out.push(finding(
                ctx,
                RuleId::L004,
                code[k].line,
                format!(
                    "`{}()` on a socket this fn obtained, with no set_read_timeout/\
                     set_write_timeout anywhere in it (PR 6 exporter hang)",
                    code[k].text
                ),
            ));
        }
    }
    out
}

/// L005 — `.unwrap()` / `.expect(` on the serving path (any file under
/// `coordinator/`). A panic there takes a worker thread, and with it
/// every queued request it owed a response. Fix the error path, or
/// state the safety argument inline: `// lint: allow(L005, reason)`.
fn l005_unwrap_on_serving_path(ctx: &FileContext) -> Vec<Finding> {
    if !ctx.path.contains("coordinator") {
        return Vec::new();
    }
    let code = &ctx.code;
    let mut out = Vec::new();
    for (i, t) in code.iter().enumerate() {
        if t.kind == TokKind::Ident
            && (t.text == "unwrap" || t.text == "expect")
            && i > 0
            && code[i - 1].is_punct(".")
            && matches!(code.get(i + 1), Some(u) if u.is_punct("("))
        {
            out.push(finding(
                ctx,
                RuleId::L005,
                t.line,
                format!(
                    "`.{}()` on the serving path; handle the error, or justify it with \
                     `// lint: allow(L005, reason)`",
                    t.text
                ),
            ));
        }
    }
    out
}

/// L006 — `==`/`!=` against a floating-point literal. The RAV cache
/// keys floats by quantized buckets precisely because raw equality
/// drifts; `dse/rav.rs` and `dse/cache.rs` (the blessed quantizers) are
/// exempt. Exact-zero sentinels elsewhere carry an allow-annotation
/// stating why the value is exact.
fn l006_float_equality(ctx: &FileContext) -> Vec<Finding> {
    if ctx.file_name == "rav.rs" || ctx.file_name == "cache.rs" {
        return Vec::new();
    }
    let code = &ctx.code;
    let mut out = Vec::new();
    for (i, t) in code.iter().enumerate() {
        if !(t.is_punct("==") || t.is_punct("!=")) {
            continue;
        }
        let float_neighbor = [i.wrapping_sub(1), i + 1].into_iter().any(|k| {
            matches!(code.get(k),
                Some(u) if u.kind == TokKind::Num && is_float_literal(&u.text))
        });
        if float_neighbor {
            out.push(finding(
                ctx,
                RuleId::L006,
                t.line,
                format!(
                    "float `{}` against a literal; compare quantized keys or use an \
                     epsilon (RAV cache-key drift), or annotate why the value is exact",
                    t.text
                ),
            ));
        }
    }
    out
}

/// L007 — `thread::spawn` (anonymous thread). Unnamed threads make
/// panics, profiles, and `/proc` inspection unattributable; spawn via
/// `thread::Builder::new().name(...)` instead. The Builder's `.spawn(`
/// method form is inherently not matched by the `thread :: spawn`
/// token pattern.
fn l007_unnamed_thread(ctx: &FileContext) -> Vec<Finding> {
    let code = &ctx.code;
    let mut out = Vec::new();
    for (i, t) in code.iter().enumerate() {
        if t.is_ident("thread")
            && matches!(code.get(i + 1), Some(u) if u.is_punct("::"))
            && matches!(code.get(i + 2), Some(u) if u.is_ident("spawn"))
            && matches!(code.get(i + 3), Some(u) if u.is_punct("("))
        {
            out.push(finding(
                ctx,
                RuleId::L007,
                t.line,
                "unnamed thread; spawn via thread::Builder::new().name(...) so panics \
                 and profiles are attributable"
                    .to_string(),
            ));
        }
    }
    out
}

/// L008 — `SystemTime::now()` under `coordinator/`. The serving and
/// tracing path must be monotonic: span timestamps, latency samples,
/// and heartbeat horizons all difference two readings, and the wall
/// clock can step backwards under NTP — which yields negative phase
/// durations and spurious ejections. Use `Instant` (against a module
/// epoch where an absolute scale is needed, as `trace.rs` does). A
/// deliberate wall-clock read (e.g. stamping an export file name)
/// carries `// lint: allow(L008, reason)`.
fn l008_wall_clock_on_serving_path(ctx: &FileContext) -> Vec<Finding> {
    if !ctx.path.contains("coordinator") {
        return Vec::new();
    }
    let code = &ctx.code;
    let mut out = Vec::new();
    for (i, t) in code.iter().enumerate() {
        if t.is_ident("SystemTime")
            && matches!(code.get(i + 1), Some(u) if u.is_punct("::"))
            && matches!(code.get(i + 2), Some(u) if u.is_ident("now"))
            && matches!(code.get(i + 3), Some(u) if u.is_punct("("))
        {
            out.push(finding(
                ctx,
                RuleId::L008,
                t.line,
                "`SystemTime::now()` on the serving/tracing path; the wall clock can \
                 step backwards — use `Instant` (against an epoch for absolute \
                 timestamps), or justify with `// lint: allow(L008, reason)`"
                    .to_string(),
            ));
        }
    }
    out
}

/// L009 — host-entropy randomness under `workload/` or `benches/`.
/// Those scopes promise bit-determinism: traces replay byte-identical
/// from a seed, and bench runs reproduce across machines. Anything that
/// draws from process entropy breaks that silently — `RandomState`
/// (the std HashMap/HashSet default hasher, reseeded per process, so
/// iteration order changes run to run), `thread_rng`/`from_entropy`/
/// `random`, and wall-clock reads used as ad-hoc seeds. Use
/// `util::rng::Rng::seed_from_u64` (with a per-item counter mix for
/// parallel streams) and `BTreeMap`/`BTreeSet` for keyed collections.
fn l009_unseeded_randomness(ctx: &FileContext) -> Vec<Finding> {
    if !(ctx.path.contains("workload") || ctx.path.contains("benches")) {
        return Vec::new();
    }
    let code = &ctx.code;
    let mut out = Vec::new();
    for (i, t) in code.iter().enumerate() {
        if t.is_ident("RandomState") {
            out.push(finding(
                ctx,
                RuleId::L009,
                t.line,
                "`RandomState` is reseeded from process entropy; deterministic scopes \
                 need a fixed-seed hasher or an ordered collection"
                    .to_string(),
            ));
        } else if (t.is_ident("HashMap") || t.is_ident("HashSet"))
            && matches!(code.get(i + 1), Some(u) if u.is_punct("::"))
            && matches!(code.get(i + 2),
                Some(u) if u.is_ident("new") || u.is_ident("with_capacity"))
            && matches!(code.get(i + 3), Some(u) if u.is_punct("("))
        {
            out.push(finding(
                ctx,
                RuleId::L009,
                t.line,
                format!(
                    "`{}` hashes with per-process `RandomState`, so iteration order is \
                     nondeterministic; use BTreeMap/BTreeSet in trace/bench code",
                    t.text
                ),
            ));
        } else if is_call_of(code, i, ENTROPY_CALLS) {
            out.push(finding(
                ctx,
                RuleId::L009,
                t.line,
                format!(
                    "`{}()` draws from host entropy; seed `util::rng::Rng::seed_from_u64` \
                     from the spec so traces replay bit-identically",
                    t.text
                ),
            ));
        } else if t.is_ident("SystemTime")
            && matches!(code.get(i + 1), Some(u) if u.is_punct("::"))
            && matches!(code.get(i + 2), Some(u) if u.is_ident("now"))
            && matches!(code.get(i + 3), Some(u) if u.is_punct("("))
        {
            out.push(finding(
                ctx,
                RuleId::L009,
                t.line,
                "wall-clock read in deterministic trace/bench code — a timestamp seed \
                 makes every run unreproducible; thread the seed through the spec \
                 instead, or justify with `// lint: allow(L009, reason)`"
                    .to_string(),
            ));
        }
    }
    out
}
