//! Repo-native static analysis: machine-checked invariants distilled
//! from bugs that earlier PRs found by hand.
//!
//! Each rule encodes one historical failure mode of this codebase (the
//! PR 2 admission-lock convoy, the PR 6 sibling-failover double-count,
//! EDF slack-index leak, and metrics-exporter hang), so a regression
//! trips the linter instead of a 2 a.m. pager. The engine is
//! deliberately self-contained — a hand-rolled lexer ([`lexer`]) plus
//! token-pattern rules ([`rules`]) — so it adds no dependencies and
//! runs in the ordinary test/CI loop via `dnnexplorer lint`.
//!
//! Suppression is explicit and auditable:
//! * `// lint: allow(L00N, reason)` on (or directly above) a line
//!   waives one rule there; the reason is part of the grammar.
//! * A JSON baseline file ([`baseline`]) waives pre-existing findings
//!   per `(rule, file)` so the gate can be adopted incrementally.
//! * Code under `#[cfg(test)]` / `#[test]` is exempt wholesale — tests
//!   do sketchy things on purpose.

pub mod baseline;
pub mod lexer;
pub mod rules;

use std::collections::HashSet;
use std::path::{Path, PathBuf};

use lexer::{lex, Tok};

/// Identifier of one lint rule. Every rule corresponds to a bug class
/// this repo has actually shipped (see [`RuleId::summary`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum RuleId {
    /// Mutex guard held across a blocking call (PR 2 lock convoy).
    L001,
    /// Metrics counter mutated outside its helper (PR 6 double-count).
    L002,
    /// Unbounded collection growth in a worker loop (PR 6 slack leak).
    L003,
    /// Socket I/O without timeouts (PR 6 exporter hang).
    L004,
    /// `unwrap`/`expect` on the serving path.
    L005,
    /// Raw floating-point equality (RAV cache-key drift).
    L006,
    /// Unnamed spawned thread.
    L007,
    /// Wall-clock `SystemTime::now()` on the serving/tracing path.
    L008,
    /// Unseeded randomness in trace generation / benches.
    L009,
}

impl RuleId {
    /// Every rule, in reporting order.
    pub fn all() -> [RuleId; 9] {
        [
            RuleId::L001,
            RuleId::L002,
            RuleId::L003,
            RuleId::L004,
            RuleId::L005,
            RuleId::L006,
            RuleId::L007,
            RuleId::L008,
            RuleId::L009,
        ]
    }

    /// Stable textual code (`"L001"`), as used in CLI flags, allow
    /// annotations, and baseline files.
    pub fn code(self) -> &'static str {
        match self {
            RuleId::L001 => "L001",
            RuleId::L002 => "L002",
            RuleId::L003 => "L003",
            RuleId::L004 => "L004",
            RuleId::L005 => "L005",
            RuleId::L006 => "L006",
            RuleId::L007 => "L007",
            RuleId::L008 => "L008",
            RuleId::L009 => "L009",
        }
    }

    /// Parse a textual code back into a rule id.
    pub fn parse(s: &str) -> Option<RuleId> {
        RuleId::all().into_iter().find(|r| r.code() == s)
    }

    /// One-line statement of the invariant the rule checks.
    pub fn summary(self) -> &'static str {
        match self {
            RuleId::L001 => "mutex guard held across a blocking call",
            RuleId::L002 => "metrics counter mutated outside its helpers",
            RuleId::L003 => "unbounded collection growth in a worker loop",
            RuleId::L004 => "socket I/O without read/write timeouts",
            RuleId::L005 => "unwrap/expect on the serving path",
            RuleId::L006 => "raw floating-point equality",
            RuleId::L007 => "unnamed spawned thread",
            RuleId::L008 => "wall-clock SystemTime::now() on the serving/tracing path",
            RuleId::L009 => "unseeded randomness in trace generation or benches",
        }
    }
}

impl std::fmt::Display for RuleId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.code())
    }
}

/// One lint finding: where, which rule, and why it matters.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: RuleId,
    /// Path as given to the analyzer (repo-relative in CLI use).
    pub file: String,
    /// 1-based source line.
    pub line: u32,
    pub message: String,
}

/// Lexed view of one source file plus the suppression state the rules
/// consult: allow-annotations and `#[cfg(test)]` line ranges.
pub struct FileContext {
    /// Path the file was given as (used for path-scoped rules).
    pub path: String,
    /// Final component of the path (used for file-scoped exemptions).
    pub file_name: String,
    /// Token stream with comments stripped.
    pub code: Vec<Tok>,
    allowed: HashSet<(RuleId, u32)>,
    test_ranges: Vec<(u32, u32)>,
}

impl FileContext {
    /// Lex `src` and precompute suppression state.
    pub fn build(path: &str, src: &str) -> FileContext {
        let toks = lex(src);

        // `// lint: allow(L00N, reason)` waives the rule on the
        // comment's own line and on the next code line after it (the
        // annotation conventionally sits directly above the finding).
        let mut allowed = HashSet::new();
        for (i, t) in toks.iter().enumerate() {
            if !t.is_comment() {
                continue;
            }
            let Some(rule) = parse_allow(&t.text) else { continue };
            allowed.insert((rule, t.line));
            if let Some(next) = toks[i + 1..].iter().find(|u| !u.is_comment()) {
                allowed.insert((rule, next.line));
            }
        }

        let code: Vec<Tok> = toks.into_iter().filter(|t| !t.is_comment()).collect();
        let test_ranges = test_ranges(&code);
        let file_name = path.rsplit(['/', '\\']).next().unwrap_or(path).to_string();
        FileContext { path: path.to_string(), file_name, code, allowed, test_ranges }
    }

    /// Whether `line` falls inside a `#[cfg(test)]` / `#[test]` item.
    pub fn is_test_line(&self, line: u32) -> bool {
        self.test_ranges.iter().any(|&(a, b)| line >= a && line <= b)
    }

    /// Whether an allow-annotation waives `rule` on `line`.
    pub fn is_allowed(&self, rule: RuleId, line: u32) -> bool {
        self.allowed.contains(&(rule, line))
    }
}

/// Extract the rule id from a `lint: allow(...)` comment, if any.
fn parse_allow(comment: &str) -> Option<RuleId> {
    let idx = comment.find("lint: allow(")?;
    let rest = &comment[idx + "lint: allow(".len()..];
    let end = rest.find(|c: char| c == ',' || c == ')')?;
    RuleId::parse(rest[..end].trim())
}

/// Index of the token closing the group opened at `open_idx`, matching
/// `open`/`close` punct texts by depth. Token-level, so delimiters
/// inside string/char literals cannot unbalance it.
pub(crate) fn matching(code: &[Tok], open_idx: usize, open: &str, close: &str) -> Option<usize> {
    let mut depth = 0i32;
    for (k, t) in code.iter().enumerate().skip(open_idx) {
        if t.is_punct(open) {
            depth += 1;
        } else if t.is_punct(close) {
            depth -= 1;
            if depth == 0 {
                return Some(k);
            }
        }
    }
    None
}

/// Line ranges covered by test-only items: any `#[...]` attribute whose
/// tokens include the ident `test` (`#[test]`, `#[cfg(test)]`,
/// `#[cfg(all(test, ...))]`), extended over the annotated item — up to
/// the matching `}` of its body, or the `;` of a body-less item.
fn test_ranges(code: &[Tok]) -> Vec<(u32, u32)> {
    let mut ranges = Vec::new();
    let mut i = 0usize;
    while i < code.len() {
        if !(code[i].is_punct("#")
            && matches!(code.get(i + 1), Some(t) if t.is_punct("[")))
        {
            i += 1;
            continue;
        }
        let Some(close) = matching(code, i + 1, "[", "]") else {
            i += 1;
            continue;
        };
        let is_test = code[i + 2..close].iter().any(|t| t.is_ident("test"));
        if !is_test {
            i = close + 1;
            continue;
        }
        let attr_line = code[i].line;
        // Skip any further attributes on the same item.
        let mut j = close + 1;
        while matches!(code.get(j), Some(t) if t.is_punct("#"))
            && matches!(code.get(j + 1), Some(t) if t.is_punct("["))
        {
            match matching(code, j + 1, "[", "]") {
                Some(c) => j = c + 1,
                None => break,
            }
        }
        // The annotated item ends at its body's `}` or at a `;`.
        let mut end_line = code.last().map_or(attr_line, |t| t.line);
        while j < code.len() {
            if code[j].is_punct(";") {
                end_line = code[j].line;
                break;
            }
            if code[j].is_punct("{") {
                if let Some(c) = matching(code, j, "{", "}") {
                    end_line = code[c].line;
                }
                break;
            }
            j += 1;
        }
        ranges.push((attr_line, end_line));
        i = close + 1;
    }
    ranges
}

/// Analyze one file's source text. Findings in test regions or waived
/// by allow-annotations are already filtered; the result is sorted by
/// line and deduplicated per `(rule, line)`.
pub fn analyze_source(path: &str, src: &str, active: &[RuleId]) -> Vec<Finding> {
    let ctx = FileContext::build(path, src);
    let mut findings = Vec::new();
    for &rule in active {
        findings.extend(rules::run(rule, &ctx));
    }
    findings.retain(|f| !ctx.is_test_line(f.line) && !ctx.is_allowed(f.rule, f.line));
    findings.sort_by_key(|f| (f.line, f.rule));
    findings.dedup_by(|a, b| a.rule == b.rule && a.line == b.line);
    findings
}

/// Result of analyzing a file tree.
pub struct Report {
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
}

/// Analyze every `.rs` file under `root` (or `root` itself if it is a
/// file), skipping `target/`, `vendor/`, and hidden directories.
/// Findings come back sorted by `(file, line, rule)`.
pub fn analyze_tree(root: &Path, active: &[RuleId]) -> anyhow::Result<Report> {
    let mut files = Vec::new();
    if root.is_file() {
        files.push(root.to_path_buf());
    } else {
        collect_rs_files(root, &mut files)?;
    }
    files.sort();
    let mut findings = Vec::new();
    for path in &files {
        let src = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
        let display = path.to_string_lossy().replace('\\', "/");
        findings.extend(analyze_source(&display, &src, active));
    }
    findings.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    Ok(Report { findings, files_scanned: files.len() })
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> anyhow::Result<()> {
    let entries = std::fs::read_dir(dir)
        .map_err(|e| anyhow::anyhow!("read dir {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry?;
        let path = entry.path();
        let name = entry.file_name().to_string_lossy().into_owned();
        if path.is_dir() {
            if name == "target" || name == "vendor" || name.starts_with('.') {
                continue;
            }
            collect_rs_files(&path, out)?;
        } else if name.ends_with(".rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allow_annotation_covers_comment_and_next_code_line() {
        let src = "fn f(v: Option<u64>) -> u64 {\n\
                   // lint: allow(L005, justified)\n\
                   v.unwrap()\n\
                   }\n";
        let ctx = FileContext::build("src/coordinator/x.rs", src);
        assert!(ctx.is_allowed(RuleId::L005, 2));
        assert!(ctx.is_allowed(RuleId::L005, 3));
        assert!(!ctx.is_allowed(RuleId::L005, 4));
        assert!(!ctx.is_allowed(RuleId::L001, 3));
        let findings = analyze_source("src/coordinator/x.rs", src, &RuleId::all());
        assert!(findings.is_empty(), "{findings:?}");
    }

    #[test]
    fn cfg_test_regions_are_detected() {
        let src = "fn live() {}\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                   fn helper() {}\n\
                   #[test]\n\
                   fn t() {}\n\
                   }\n\
                   fn live2() {}\n";
        let ctx = FileContext::build("src/x.rs", src);
        assert!(!ctx.is_test_line(1));
        assert!(ctx.is_test_line(2));
        assert!(ctx.is_test_line(4));
        assert!(ctx.is_test_line(6));
        assert!(ctx.is_test_line(7));
        assert!(!ctx.is_test_line(8));
    }

    #[test]
    fn cfg_test_on_bodyless_item_spans_to_semicolon() {
        let src = "#[cfg(test)]\nuse std::sync::Mutex;\nfn live() {}\n";
        let ctx = FileContext::build("src/x.rs", src);
        assert!(ctx.is_test_line(2));
        assert!(!ctx.is_test_line(3));
    }

    #[test]
    fn rule_id_round_trips() {
        for r in RuleId::all() {
            assert_eq!(RuleId::parse(r.code()), Some(r));
        }
        assert_eq!(RuleId::parse("L999"), None);
    }
}
