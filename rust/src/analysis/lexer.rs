//! Hand-rolled Rust lexer for the repo lint engine.
//!
//! The rules in [`super::rules`] match on *token* patterns, so the lexer
//! only has to be faithful about the things that would otherwise corrupt
//! a match: comments (including nested block comments), string literals
//! (including raw strings, where `//` and `"` are just bytes), char
//! literals vs lifetimes (`'a'` vs `'a`), and float literals vs ranges
//! (`1.5` vs `0..10`). It does not need to classify keywords, resolve
//! paths, or get numeric suffixes perfectly right — tokens carry their
//! raw text and the rules match on it.
//!
//! Every token records the source line it *starts* on, which is the line
//! findings are reported at and the line allow-annotations attach to.

/// Lexical class of a token.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`state`, `fn`, `loop`, ...).
    Ident,
    /// Numeric literal, raw text preserved (`10`, `0.3`, `1e-6`, `0xff`).
    Num,
    /// String or byte-string literal, quotes included.
    Str,
    /// Raw (byte-)string literal: `r"..."`, `r#"..."#`, `br#"..."#`.
    RawStr,
    /// Char or byte-char literal: `'x'`, `'\n'`, `b'q'`.
    Char,
    /// Lifetime: `'a`, `'static` (also loop labels: `'run`).
    Lifetime,
    /// Operator / punctuation. Multi-char operators the rules depend on
    /// (`==`, `!=`, `::`, `..`, `->`, ...) are kept as single tokens.
    Punct,
    /// `// ...` to end of line.
    LineComment,
    /// `/* ... */`, nesting-aware.
    BlockComment,
}

/// One lexed token: kind, raw text, and 1-based start line.
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

impl Tok {
    /// True for comment tokens (stripped before rule matching).
    pub fn is_comment(&self) -> bool {
        matches!(self.kind, TokKind::LineComment | TokKind::BlockComment)
    }

    /// True when this is an [`TokKind::Ident`] with exactly this text.
    pub fn is_ident(&self, text: &str) -> bool {
        self.kind == TokKind::Ident && self.text == text
    }

    /// True when this is a [`TokKind::Punct`] with exactly this text.
    pub fn is_punct(&self, text: &str) -> bool {
        self.kind == TokKind::Punct && self.text == text
    }
}

/// Two-char operators kept atomic. `..=` is handled as an extension of
/// `..`; triples like `<<=` split into `<<` + `=`, which no rule cares
/// about.
const TWO_CHAR_OPS: &[&str] = &[
    "==", "!=", "<=", ">=", "::", "->", "=>", "&&", "||", "..", "+=", "-=", "*=", "/=", "%=",
    "<<", ">>", "&=", "|=", "^=",
];

/// Lex `src` into a flat token stream. Never fails: malformed input
/// degrades to stray `Punct` tokens rather than panicking, so the lint
/// engine stays usable on half-edited files.
pub fn lex(src: &str) -> Vec<Tok> {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut toks: Vec<Tok> = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;

    let collect = |lo: usize, hi: usize| -> String { chars[lo..hi].iter().collect() };

    while i < n {
        let c = chars[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }

        // Line comment.
        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            let start = i;
            while i < n && chars[i] != '\n' {
                i += 1;
            }
            toks.push(Tok { kind: TokKind::LineComment, text: collect(start, i), line });
            continue;
        }

        // Block comment, nesting-aware.
        if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            let start = i;
            let start_line = line;
            let mut depth = 1u32;
            i += 2;
            while i < n && depth > 0 {
                if chars[i] == '/' && i + 1 < n && chars[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if chars[i] == '*' && i + 1 < n && chars[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    if chars[i] == '\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            toks.push(Tok {
                kind: TokKind::BlockComment,
                text: collect(start, i),
                line: start_line,
            });
            continue;
        }

        // Raw strings: r"..." / r#"..."# / br"..." — checked before
        // identifiers because a bare `r` is a valid ident start.
        if c == 'r' || (c == 'b' && i + 1 < n && chars[i + 1] == 'r') {
            let mut j = i + if c == 'b' { 2 } else { 1 };
            let mut hashes = 0usize;
            while j < n && chars[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if j < n && chars[j] == '"' {
                let start = i;
                let start_line = line;
                j += 1;
                while j < n {
                    if chars[j] == '\n' {
                        line += 1;
                        j += 1;
                        continue;
                    }
                    if chars[j] == '"' {
                        let mut k = 0usize;
                        while k < hashes && j + 1 + k < n && chars[j + 1 + k] == '#' {
                            k += 1;
                        }
                        if k == hashes {
                            j += 1 + hashes;
                            break;
                        }
                    }
                    j += 1;
                }
                toks.push(Tok { kind: TokKind::RawStr, text: collect(start, j), line: start_line });
                i = j;
                continue;
            }
            // Not a raw string (`rx`, `break`, ...): fall through.
        }

        // String / byte-string literal.
        if c == '"' || (c == 'b' && i + 1 < n && chars[i + 1] == '"') {
            let start = i;
            let start_line = line;
            i += if c == 'b' { 2 } else { 1 };
            while i < n {
                match chars[i] {
                    '\\' => {
                        // Skip the escaped char; a `\` before a newline
                        // is a line continuation — keep the line count.
                        if i + 1 < n && chars[i + 1] == '\n' {
                            line += 1;
                        }
                        i += 2;
                    }
                    '"' => {
                        i += 1;
                        break;
                    }
                    '\n' => {
                        line += 1;
                        i += 1;
                    }
                    _ => i += 1,
                }
            }
            toks.push(Tok { kind: TokKind::Str, text: collect(start, i), line: start_line });
            continue;
        }

        // Char literal vs lifetime.
        if c == '\'' || (c == 'b' && i + 1 < n && chars[i + 1] == '\'') {
            let start = i;
            let is_byte = c == 'b';
            let q = if is_byte { i + 1 } else { i };
            let mut j = q + 1;
            if j < n && chars[j] == '\\' {
                // Escaped char: '\n', '\\', '\u{1F600}'.
                j += 2;
                if j > 0 && j - 1 < n && chars[j - 1] == 'u' && j < n && chars[j] == '{' {
                    while j < n && chars[j] != '}' {
                        j += 1;
                    }
                    j += 1;
                }
                if j < n && chars[j] == '\'' {
                    j += 1;
                }
                toks.push(Tok { kind: TokKind::Char, text: collect(start, j), line });
                i = j;
                continue;
            }
            if j < n && (chars[j].is_alphanumeric() || chars[j] == '_') {
                let mut k = j;
                while k < n && (chars[k].is_alphanumeric() || chars[k] == '_') {
                    k += 1;
                }
                if k == j + 1 && k < n && chars[k] == '\'' {
                    // Exactly one ident-ish char then a closing quote:
                    // a char literal like 'x' or '_'.
                    toks.push(Tok { kind: TokKind::Char, text: collect(start, k + 1), line });
                    i = k + 1;
                } else {
                    // An ident run with no closing quote: a lifetime.
                    toks.push(Tok { kind: TokKind::Lifetime, text: collect(start, k), line });
                    i = k;
                }
                continue;
            }
            // Non-ident char like '+' or ' '.
            if j + 1 < n && chars[j + 1] == '\'' {
                toks.push(Tok { kind: TokKind::Char, text: collect(start, j + 2), line });
                i = j + 2;
                continue;
            }
            // Stray quote in malformed input: degrade to punct.
            toks.push(Tok { kind: TokKind::Punct, text: "'".to_string(), line });
            i = q + 1;
            continue;
        }

        // Numeric literal.
        if c.is_ascii_digit() {
            let start = i;
            if c == '0'
                && i + 1 < n
                && (chars[i + 1] == 'x' || chars[i + 1] == 'b' || chars[i + 1] == 'o')
            {
                i += 2;
                while i < n && (chars[i].is_ascii_alphanumeric() || chars[i] == '_') {
                    i += 1;
                }
            } else {
                while i < n && (chars[i].is_ascii_digit() || chars[i] == '_') {
                    i += 1;
                }
                // A dot continues the literal only when it is a real
                // fractional part — not `0..10`, not `1.to_string()`.
                if i < n && chars[i] == '.' {
                    let frac = match chars.get(i + 1).copied() {
                        Some(d) if d.is_ascii_digit() => true,
                        Some('.') => false,
                        Some(ch) if ch.is_alphabetic() || ch == '_' => false,
                        _ => true, // trailing `1.`
                    };
                    if frac {
                        i += 1;
                        while i < n && (chars[i].is_ascii_digit() || chars[i] == '_') {
                            i += 1;
                        }
                    }
                }
                if i < n && (chars[i] == 'e' || chars[i] == 'E') {
                    let mut j = i + 1;
                    if j < n && (chars[j] == '+' || chars[j] == '-') {
                        j += 1;
                    }
                    if j < n && chars[j].is_ascii_digit() {
                        i = j;
                        while i < n && (chars[i].is_ascii_digit() || chars[i] == '_') {
                            i += 1;
                        }
                    }
                }
                // Type suffix (f64, u32, usize, ...).
                while i < n && (chars[i].is_ascii_alphanumeric() || chars[i] == '_') {
                    i += 1;
                }
            }
            toks.push(Tok { kind: TokKind::Num, text: collect(start, i), line });
            continue;
        }

        // Identifier / keyword.
        if c.is_alphabetic() || c == '_' {
            let start = i;
            while i < n && (chars[i].is_alphanumeric() || chars[i] == '_') {
                i += 1;
            }
            toks.push(Tok { kind: TokKind::Ident, text: collect(start, i), line });
            continue;
        }

        // Punctuation, multi-char ops combined.
        if i + 1 < n {
            let pair: String = chars[i..i + 2].iter().collect();
            if TWO_CHAR_OPS.contains(&pair.as_str()) {
                if pair == ".." && i + 2 < n && chars[i + 2] == '=' {
                    toks.push(Tok { kind: TokKind::Punct, text: "..=".to_string(), line });
                    i += 3;
                    continue;
                }
                toks.push(Tok { kind: TokKind::Punct, text: pair, line });
                i += 2;
                continue;
            }
        }
        toks.push(Tok { kind: TokKind::Punct, text: c.to_string(), line });
        i += 1;
    }

    toks
}

/// Whether a [`TokKind::Num`] token's text denotes a float literal.
/// `0usize` must not count (the `e` in `usize` is not an exponent), and
/// neither must hex literals like `0x1e5`.
pub fn is_float_literal(text: &str) -> bool {
    if text.starts_with("0x") || text.starts_with("0b") || text.starts_with("0o") {
        return false;
    }
    if text.ends_with("f32") || text.ends_with("f64") || text.contains('.') {
        return true;
    }
    let bytes = text.as_bytes();
    for (i, &b) in bytes.iter().enumerate() {
        if (b == b'e' || b == b'E') && i > 0 {
            if let Some(&next) = bytes.get(i + 1) {
                if next.is_ascii_digit() || next == b'+' || next == b'-' {
                    return true;
                }
            }
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn nested_block_comments_are_one_token() {
        let toks = kinds("/* a /* b */ c */ x");
        assert_eq!(toks.len(), 2);
        assert_eq!(toks[0].0, TokKind::BlockComment);
        assert_eq!(toks[0].1, "/* a /* b */ c */");
        assert_eq!(toks[1], (TokKind::Ident, "x".to_string()));
    }

    #[test]
    fn block_comment_line_counting() {
        let toks = lex("/* one\ntwo\nthree */ after");
        assert_eq!(toks[0].line, 1);
        assert_eq!(toks[1].line, 3);
        assert_eq!(toks[1].text, "after");
    }

    #[test]
    fn raw_strings_swallow_comment_markers_and_quotes() {
        let toks = kinds(r###"r#"thread::spawn // "quoted""# x"###);
        assert_eq!(toks.len(), 2);
        assert_eq!(toks[0].0, TokKind::RawStr);
        assert_eq!(toks[1], (TokKind::Ident, "x".to_string()));
    }

    #[test]
    fn byte_raw_string_and_byte_string() {
        let toks = kinds(r#"br"raw" b"bytes" b'q'"#);
        assert_eq!(
            toks.iter().map(|(k, _)| *k).collect::<Vec<_>>(),
            vec![TokKind::RawStr, TokKind::Str, TokKind::Char]
        );
    }

    #[test]
    fn comment_marker_inside_string_is_not_a_comment() {
        let toks = kinds(r#""http://x" // real"#);
        assert_eq!(toks[0].0, TokKind::Str);
        assert_eq!(toks[0].1, r#""http://x""#);
        assert_eq!(toks[1].0, TokKind::LineComment);
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let toks = kinds("<'a, 'static> 'x' '\\n' '_' 'run: loop");
        let lifetimes: Vec<_> = toks
            .iter()
            .filter(|(k, _)| *k == TokKind::Lifetime)
            .map(|(_, t)| t.clone())
            .collect();
        let chars: Vec<_> = toks
            .iter()
            .filter(|(k, _)| *k == TokKind::Char)
            .map(|(_, t)| t.clone())
            .collect();
        assert_eq!(lifetimes, vec!["'a", "'static", "'run"]);
        assert_eq!(chars, vec!["'x'", "'\\n'", "'_'"]);
    }

    #[test]
    fn ranges_are_not_floats() {
        let toks = kinds("0..10 1.5 1..=3 2.0f64 7.max(1)");
        let nums: Vec<_> = toks
            .iter()
            .filter(|(k, _)| *k == TokKind::Num)
            .map(|(_, t)| t.clone())
            .collect();
        assert_eq!(nums, vec!["0", "10", "1.5", "1", "3", "2.0f64", "7", "1"]);
        assert!(toks.contains(&(TokKind::Punct, "..".to_string())));
        assert!(toks.contains(&(TokKind::Punct, "..=".to_string())));
    }

    #[test]
    fn float_literal_classification() {
        assert!(is_float_literal("1.5"));
        assert!(is_float_literal("2.0f64"));
        assert!(is_float_literal("3f32"));
        assert!(is_float_literal("1e-6"));
        assert!(is_float_literal("1E+9"));
        assert!(!is_float_literal("10"));
        assert!(!is_float_literal("0usize"));
        assert!(!is_float_literal("0x1e5"));
        assert!(!is_float_literal("1_000"));
    }

    #[test]
    fn multi_char_operators_stay_atomic() {
        let toks = kinds("a == b != c :: d -> e => f");
        let puncts: Vec<_> = toks
            .iter()
            .filter(|(k, _)| *k == TokKind::Punct)
            .map(|(_, t)| t.clone())
            .collect();
        assert_eq!(puncts, vec!["==", "!=", "::", "->", "=>"]);
    }

    #[test]
    fn attribute_tokens() {
        let toks = kinds("#[cfg(test)]");
        let texts: Vec<_> = toks.iter().map(|(_, t)| t.as_str()).collect();
        assert_eq!(texts, vec!["#", "[", "cfg", "(", "test", ")", "]"]);
    }
}
