//! Trace-driven load campaigns: a seeded, bit-deterministic workload
//! generator plus a pacing replayer that drives a
//! [`crate::coordinator::ShardedPipeline`] at recorded timestamps.
//!
//! ## Arrival model
//!
//! Arrivals follow a non-homogeneous Poisson process. The instantaneous
//! rate is the base rate modulated by two factors:
//!
//! * a **diurnal** sinusoid — `1 + A·sin(2πt/P)` — the slow daily
//!   swing every serving fleet sees;
//! * a two-state **Markov burst** process — each arrival flips a
//!   burst episode on with probability `burst_start_p` (off with
//!   `burst_stop_p`), and while an episode is live the rate multiplies
//!   by `burst_multiplier`. Episode lengths are therefore geometric,
//!   which produces the heavy-tailed clumping that defeats
//!   average-rate capacity planning.
//!
//! The three [`Profile`]s are just parameter presets: `steady` turns
//! both factors off, `diurnal` turns on the sinusoid, `bursty` both.
//!
//! ## Frame mix
//!
//! Each record draws a tenant and a frame key from Pareto-ish power
//! laws (`weight(i) ∝ (i+1)^-α`), so low-index tenants dominate the
//! request mix and a small set of hot frame keys repeats often enough
//! for content-keyed dedup to matter.
//!
//! ## Determinism
//!
//! Generation is bit-identical for a fixed [`TraceSpec`] at any thread
//! count, and across a save→load round trip:
//!
//! * **Phase A** (sequential) walks one [`Rng`] stream for the arrival
//!   gaps and the burst chain — the only state that is inherently
//!   serial.
//! * **Phase B** (parallel over [`crate::util::parallel::parallel_map`],
//!   which preserves input order) derives each record's tenant, frame
//!   key, and deadline from a *counter-based* RNG seeded by
//!   `seed ^ mix(i)` — no cross-record state, so the split into
//!   threads cannot matter.
//!
//! All randomness flows through [`crate::util::rng::Rng`]; lint rule
//! L009 keeps unseeded entropy (hash-map iteration order, thread
//! RNGs, wall clocks) out of this module and the benches.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

use crate::coordinator::queue::ServeError;
use crate::coordinator::ShardedPipeline;
use crate::runtime::executable::HostTensor;
use crate::util::json::Json;
use crate::util::pace::Pacer;
use crate::util::parallel::parallel_map;
use crate::util::rng::Rng;

/// One generated request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceRecord {
    /// Arrival offset from campaign start, microseconds.
    pub arrival_us: u64,
    /// Tenant class index (dense, `0..spec.tenants`).
    pub tenant: u32,
    /// Content key; hot keys repeat (dedup-relevant).
    pub frame_key: u64,
    /// Latency deadline as an absolute campaign offset
    /// (`arrival_us + slack`). Recorded for downstream consumers; the
    /// replayer itself does not enforce it.
    pub deadline_us: u64,
}

/// Workload shape preset. See the module docs for the model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Profile {
    /// Homogeneous Poisson at the base rate.
    Steady,
    /// Sinusoidal rate swing, no bursts.
    Diurnal,
    /// Sinusoid plus Markov-modulated burst episodes.
    Bursty,
}

impl Profile {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "steady" => Ok(Profile::Steady),
            "diurnal" => Ok(Profile::Diurnal),
            "bursty" => Ok(Profile::Bursty),
            other => anyhow::bail!("unknown profile {other:?} (want steady|diurnal|bursty)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Profile::Steady => "steady",
            Profile::Diurnal => "diurnal",
            Profile::Bursty => "bursty",
        }
    }
}

/// Full generator parameterization. [`TraceSpec::new`] fills
/// profile-appropriate defaults; every field stays overridable so
/// tests can pin exact shapes.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceSpec {
    pub requests: usize,
    pub base_rate_hz: f64,
    pub tenants: u32,
    pub profile: Profile,
    pub seed: u64,
    /// Diurnal period, seconds (compressed from 24h so short campaigns
    /// still sweep a full cycle).
    pub diurnal_period_s: f64,
    /// Sinusoid amplitude in [0, 1).
    pub diurnal_amplitude: f64,
    /// Rate multiplier while a burst episode is live.
    pub burst_multiplier: f64,
    /// Per-arrival probability of entering a burst episode.
    pub burst_start_p: f64,
    /// Per-arrival probability of leaving one.
    pub burst_stop_p: f64,
    /// Tenant-mix skew: `weight(t) ∝ (t+1)^-alpha`.
    pub tenant_alpha: f64,
    /// Distinct frame keys.
    pub frame_keys: u64,
    /// Frame-popularity skew (Pareto shape).
    pub frame_alpha: f64,
    /// Deadline slack added to each arrival.
    pub deadline_slack_us: u64,
}

impl TraceSpec {
    /// A profile preset at `base_rate_hz` with every other knob at its
    /// campaign default; override fields directly for custom shapes.
    pub fn new(
        profile: Profile,
        requests: usize,
        base_rate_hz: f64,
        tenants: u32,
        seed: u64,
    ) -> Self {
        let (amplitude, burst_multiplier, burst_start_p, burst_stop_p) = match profile {
            Profile::Steady => (0.0, 1.0, 0.0, 1.0),
            Profile::Diurnal => (0.6, 1.0, 0.0, 1.0),
            Profile::Bursty => (0.3, 6.0, 0.02, 0.10),
        };
        Self {
            requests,
            base_rate_hz,
            tenants: tenants.max(1),
            profile,
            seed,
            diurnal_period_s: 60.0,
            diurnal_amplitude: amplitude,
            burst_multiplier,
            burst_start_p,
            burst_stop_p,
            tenant_alpha: 1.2,
            frame_keys: 4096,
            frame_alpha: 1.1,
            deadline_slack_us: 50_000,
        }
    }
}

/// SplitMix-style index mixer for the per-record Phase B streams.
fn mix(i: u64) -> u64 {
    let mut z = (i.wrapping_add(1)).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Generate the trace for `spec`, fanning the per-record phase over up
/// to `threads` OS threads. Output is bit-identical for a fixed spec
/// at any `threads` value (see the module docs).
pub fn generate(spec: &TraceSpec, threads: usize) -> Vec<TraceRecord> {
    // Phase A (sequential): arrival gaps + burst chain on one stream.
    let mut rng = Rng::seed_from_u64(spec.seed);
    let mut t_s = 0.0f64;
    let mut burst = false;
    let mut arrivals = Vec::with_capacity(spec.requests);
    for _ in 0..spec.requests {
        if spec.burst_start_p > 0.0 {
            burst = if burst {
                !rng.gen_bool(spec.burst_stop_p)
            } else {
                rng.gen_bool(spec.burst_start_p)
            };
        }
        let diurnal = 1.0
            + spec.diurnal_amplitude
                * (2.0 * std::f64::consts::PI * t_s / spec.diurnal_period_s.max(1e-9)).sin();
        let multiplier = if burst { spec.burst_multiplier } else { 1.0 };
        let lambda = (spec.base_rate_hz * diurnal.max(0.05) * multiplier).max(1e-9);
        // gen_f64 ∈ [0,1) so 1-u ∈ (0,1] and the log is finite.
        let gap_s = -(1.0 - rng.gen_f64()).ln() / lambda;
        t_s += gap_s;
        arrivals.push((t_s * 1e6) as u64);
    }

    // Tenant mix: normalized cumulative power-law weights.
    let weights: Vec<f64> =
        (0..spec.tenants).map(|t| ((t + 1) as f64).powf(-spec.tenant_alpha)).collect();
    let total: f64 = weights.iter().sum();
    let mut acc = 0.0;
    let cum: Vec<f64> = weights
        .iter()
        .map(|w| {
            acc += w / total;
            acc
        })
        .collect();

    // Phase B (parallel, order-preserving): counter-seeded per record.
    let indexed: Vec<(u64, u64)> =
        arrivals.iter().enumerate().map(|(i, &a)| (i as u64, a)).collect();
    parallel_map(&indexed, threads, |&(i, arrival_us)| {
        let mut r = Rng::seed_from_u64(spec.seed ^ mix(i));
        let u = r.gen_f64();
        let tenant = cum.iter().position(|&c| u < c).unwrap_or(cum.len() - 1) as u32;
        let v = r.gen_f64();
        // Pareto draw over [1, ∞) truncated to the key universe.
        let draw = (1.0 / (1.0 - v)).powf(1.0 / spec.frame_alpha.max(1e-9));
        let frame_key = ((draw as u64).saturating_sub(1)).min(spec.frame_keys.saturating_sub(1));
        TraceRecord {
            arrival_us,
            tenant,
            frame_key,
            deadline_us: arrival_us.saturating_add(spec.deadline_slack_us),
        }
    })
}

/// Serialize a spec + its records as `dnnx-trace-v1` JSON (records as
/// compact `[arrival, tenant, key, deadline]` rows).
pub fn to_json(spec: &TraceSpec, records: &[TraceRecord]) -> Json {
    Json::obj(vec![
        ("format", Json::s("dnnx-trace-v1")),
        (
            "spec",
            Json::obj(vec![
                ("requests", Json::n(spec.requests as f64)),
                ("base_rate_hz", Json::n(spec.base_rate_hz)),
                ("tenants", Json::n(spec.tenants as f64)),
                ("profile", Json::s(spec.profile.name())),
                // Decimal string, not a JSON number: a full-range u64
                // seed does not survive the f64 round trip above 2^53.
                ("seed", Json::s(spec.seed.to_string())),
                ("diurnal_period_s", Json::n(spec.diurnal_period_s)),
                ("diurnal_amplitude", Json::n(spec.diurnal_amplitude)),
                ("burst_multiplier", Json::n(spec.burst_multiplier)),
                ("burst_start_p", Json::n(spec.burst_start_p)),
                ("burst_stop_p", Json::n(spec.burst_stop_p)),
                ("tenant_alpha", Json::n(spec.tenant_alpha)),
                ("frame_keys", Json::n(spec.frame_keys as f64)),
                ("frame_alpha", Json::n(spec.frame_alpha)),
                ("deadline_slack_us", Json::n(spec.deadline_slack_us as f64)),
            ]),
        ),
        (
            "records",
            Json::Arr(
                records
                    .iter()
                    .map(|r| {
                        Json::Arr(vec![
                            Json::n(r.arrival_us as f64),
                            Json::n(r.tenant as f64),
                            Json::n(r.frame_key as f64),
                            Json::n(r.deadline_us as f64),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

fn spec_f64(j: &Json, key: &str) -> anyhow::Result<f64> {
    j.get(key)
        .and_then(|v| v.as_f64())
        .ok_or_else(|| anyhow::anyhow!("trace spec missing numeric field {key:?}"))
}

/// Parse `dnnx-trace-v1` JSON back into a spec + records.
pub fn from_json(j: &Json) -> anyhow::Result<(TraceSpec, Vec<TraceRecord>)> {
    let format = j.get("format").and_then(|f| f.as_str()).unwrap_or("");
    anyhow::ensure!(format == "dnnx-trace-v1", "unsupported trace format {format:?}");
    let s = j.get("spec").ok_or_else(|| anyhow::anyhow!("trace missing spec"))?;
    let profile = Profile::parse(s.get("profile").and_then(|p| p.as_str()).unwrap_or("steady"))?;
    // Seeds are written as decimal strings (see `to_json`); accept a
    // plain number too for hand-written small-seed traces.
    let seed = match s.get("seed") {
        Some(Json::Str(v)) => {
            v.parse::<u64>().map_err(|_| anyhow::anyhow!("bad trace seed {v:?}"))?
        }
        Some(v) => v
            .as_f64()
            .map(|n| n as u64)
            .ok_or_else(|| anyhow::anyhow!("trace seed is neither string nor number"))?,
        None => anyhow::bail!("trace spec missing seed"),
    };
    let spec = TraceSpec {
        requests: spec_f64(s, "requests")? as usize,
        base_rate_hz: spec_f64(s, "base_rate_hz")?,
        tenants: spec_f64(s, "tenants")? as u32,
        profile,
        seed,
        diurnal_period_s: spec_f64(s, "diurnal_period_s")?,
        diurnal_amplitude: spec_f64(s, "diurnal_amplitude")?,
        burst_multiplier: spec_f64(s, "burst_multiplier")?,
        burst_start_p: spec_f64(s, "burst_start_p")?,
        burst_stop_p: spec_f64(s, "burst_stop_p")?,
        tenant_alpha: spec_f64(s, "tenant_alpha")?,
        frame_keys: spec_f64(s, "frame_keys")? as u64,
        frame_alpha: spec_f64(s, "frame_alpha")?,
        deadline_slack_us: spec_f64(s, "deadline_slack_us")? as u64,
    };
    let rows = j
        .get("records")
        .and_then(|r| r.as_arr())
        .ok_or_else(|| anyhow::anyhow!("trace missing records array"))?;
    let mut records = Vec::with_capacity(rows.len());
    for row in rows {
        let cells = row.as_arr().ok_or_else(|| anyhow::anyhow!("trace record not an array"))?;
        anyhow::ensure!(cells.len() == 4, "trace record wants 4 cells, got {}", cells.len());
        let cell = |k: usize| -> anyhow::Result<u64> {
            cells[k]
                .as_f64()
                .map(|v| v as u64)
                .ok_or_else(|| anyhow::anyhow!("trace record cell {k} not numeric"))
        };
        records.push(TraceRecord {
            arrival_us: cell(0)?,
            tenant: cell(1)? as u32,
            frame_key: cell(2)?,
            deadline_us: cell(3)?,
        });
    }
    Ok((spec, records))
}

/// Write a trace to disk (compact JSON).
pub fn save(path: &str, spec: &TraceSpec, records: &[TraceRecord]) -> anyhow::Result<()> {
    std::fs::write(path, to_json(spec, records).render())
        .map_err(|e| anyhow::anyhow!("write trace {path}: {e}"))
}

/// Read a trace back from disk.
pub fn load(path: &str) -> anyhow::Result<(TraceSpec, Vec<TraceRecord>)> {
    let text =
        std::fs::read_to_string(path).map_err(|e| anyhow::anyhow!("read trace {path}: {e}"))?;
    from_json(&Json::parse(&text)?)
}

/// Replay pacing/accounting knobs.
#[derive(Debug, Clone)]
pub struct ReplayOptions {
    /// Trace-time compression: offsets are divided by this, so `10.0`
    /// replays a 100-second trace in ten seconds.
    pub time_scale: f64,
    /// Invoke the tick callback every this many submissions (0 = never).
    pub tick_every: usize,
    /// How long to wait for each outstanding completion while draining.
    pub recv_timeout: Duration,
}

impl Default for ReplayOptions {
    fn default() -> Self {
        Self { time_scale: 1.0, tick_every: 256, recv_timeout: Duration::from_secs(5) }
    }
}

/// What the replayer observed. `offered == ok + failed + shed_front`
/// exactly — every submission resolves through one of the three.
#[derive(Debug, Clone, Default)]
pub struct ReplayReport {
    pub offered: u64,
    pub ok: u64,
    pub failed: u64,
    /// Refused at submission (window shed or front-queue refusal).
    pub shed_front: u64,
    pub elapsed_s: f64,
    /// Submissions per tenant index (post-clamp tenancy is the
    /// pipeline's business; this is the offered mix).
    pub per_tenant_offered: Vec<u64>,
}

/// Drive `pipe` with `records` at their recorded arrival offsets (via
/// the hybrid sleep/spin [`Pacer`] — never early, microsecond-accurate
/// under load). `on_tick` fires every [`ReplayOptions::tick_every`]
/// submissions with the current *trace-time* offset; campaign drivers
/// use it to post replica heartbeats and advance the SLO engine's
/// clock in lockstep with the trace.
pub fn replay(
    records: &[TraceRecord],
    pipe: &ShardedPipeline,
    opts: &ReplayOptions,
    mut on_tick: impl FnMut(Duration),
) -> ReplayReport {
    let scale = if opts.time_scale > 0.0 { opts.time_scale } else { 1.0 };
    let tenants = records.iter().map(|r| r.tenant as usize + 1).max().unwrap_or(1);
    let mut report = ReplayReport { per_tenant_offered: vec![0; tenants], ..Default::default() };
    let mut pending: Vec<Receiver<Result<HostTensor, ServeError>>> =
        Vec::with_capacity(records.len());
    let started = Instant::now();
    let pacer = Pacer::new(started);
    for (i, rec) in records.iter().enumerate() {
        let offset = Duration::from_micros((rec.arrival_us as f64 / scale) as u64);
        pacer.pace_until(offset);
        report.offered += 1;
        report.per_tenant_offered[rec.tenant as usize] += 1;
        let input = match HostTensor::new(vec![rec.frame_key as f32], vec![1]) {
            Ok(t) => t,
            Err(_) => {
                report.failed += 1;
                continue;
            }
        };
        match pipe.submit_frame_for(rec.tenant as usize, input) {
            Ok(rx) => pending.push(rx),
            Err(_) => report.shed_front += 1,
        }
        if opts.tick_every > 0 && (i + 1) % opts.tick_every == 0 {
            on_tick(Duration::from_micros(rec.arrival_us));
        }
    }
    for rx in pending {
        match rx.recv_timeout(opts.recv_timeout) {
            Ok(Ok(_)) => report.ok += 1,
            Ok(Err(_)) => report.failed += 1,
            Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => {
                report.failed += 1
            }
        }
    }
    if let Some(last) = records.last() {
        on_tick(Duration::from_micros(last.deadline_us));
    }
    report.elapsed_s = started.elapsed().as_secs_f64();
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(profile: Profile, n: usize) -> TraceSpec {
        TraceSpec::new(profile, n, 5_000.0, 4, 0xD11E)
    }

    #[test]
    fn arrivals_are_nondecreasing_and_complete() {
        for profile in [Profile::Steady, Profile::Diurnal, Profile::Bursty] {
            let s = spec(profile, 2_000);
            let trace = generate(&s, 4);
            assert_eq!(trace.len(), 2_000);
            for w in trace.windows(2) {
                assert!(
                    w[0].arrival_us <= w[1].arrival_us,
                    "{profile:?} arrivals must be sorted"
                );
            }
            for r in &trace {
                assert!(r.tenant < s.tenants);
                assert!(r.frame_key < s.frame_keys);
                assert_eq!(r.deadline_us, r.arrival_us + s.deadline_slack_us);
            }
        }
    }

    #[test]
    fn generation_is_bit_identical_across_thread_counts() {
        let s = spec(Profile::Bursty, 5_000);
        let one = generate(&s, 1);
        for threads in [2, 3, 8] {
            assert_eq!(one, generate(&s, threads), "threads={threads} must not change bits");
        }
    }

    #[test]
    fn tenant_mix_is_pareto_skewed() {
        let s = spec(Profile::Steady, 20_000);
        let trace = generate(&s, 4);
        let mut per = vec![0u64; s.tenants as usize];
        for r in &trace {
            per[r.tenant as usize] += 1;
        }
        assert!(
            per[0] > per[s.tenants as usize - 1] * 2,
            "head tenant {} should dominate tail {}",
            per[0],
            per[s.tenants as usize - 1]
        );
        assert!(per.iter().all(|&c| c > 0), "every tenant appears: {per:?}");
    }

    #[test]
    fn bursty_profile_clumps_harder_than_steady() {
        let n = 20_000;
        let steady = generate(&spec(Profile::Steady, n), 4);
        let bursty = generate(&spec(Profile::Bursty, n), 4);
        let p99_gap = |t: &[TraceRecord]| {
            let mut gaps: Vec<u64> =
                t.windows(2).map(|w| w[1].arrival_us - w[0].arrival_us).collect();
            gaps.sort_unstable();
            gaps[gaps.len() * 99 / 100]
        };
        let min_gap_run = |t: &[TraceRecord]| {
            // Longest run of sub-half-mean gaps — bursts make this long.
            let mean = t.last().map(|r| r.arrival_us).unwrap_or(0) / n as u64;
            let mut best = 0usize;
            let mut cur = 0usize;
            for w in t.windows(2) {
                if w[1].arrival_us - w[0].arrival_us < mean / 2 {
                    cur += 1;
                    best = best.max(cur);
                } else {
                    cur = 0;
                }
            }
            best
        };
        assert!(
            min_gap_run(&bursty) > min_gap_run(&steady),
            "bursty clump run {} should beat steady {}",
            min_gap_run(&bursty),
            min_gap_run(&steady)
        );
        // Burst episodes also stretch the tail between episodes.
        assert!(p99_gap(&bursty) != p99_gap(&steady), "profiles must differ");
    }

    #[test]
    fn save_load_round_trip_is_exact() {
        let s = spec(Profile::Bursty, 500);
        let trace = generate(&s, 2);
        let j = to_json(&s, &trace);
        let (s2, trace2) = from_json(&Json::parse(&j.render()).unwrap()).unwrap();
        assert_eq!(s, s2);
        assert_eq!(trace, trace2);
    }

    #[test]
    fn from_json_rejects_bad_shapes() {
        assert!(from_json(&Json::parse("{}").unwrap()).is_err());
        let bad = r#"{"format":"dnnx-trace-v1","spec":{"requests":1},"records":[[1,2]]}"#;
        assert!(from_json(&Json::parse(bad).unwrap()).is_err());
    }
}
