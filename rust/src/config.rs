//! Experiment configuration: a TOML-subset loadable description of an
//! exploration run, with CLI-friendly overrides.
//!
//! The offline environment has no `toml` crate; the parser accepts the
//! practical subset used by experiment files: `key = value` lines,
//! strings in double quotes, integers, and `#` comments. Tables/arrays
//! are not needed (and rejected loudly).

use crate::dnn::{Network, Precision};
use crate::dse::pso::PsoParams;
use crate::dse::ExplorerConfig;
use crate::fpga::FpgaDevice;

/// Experiment description.
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentConfig {
    /// Zoo network name (see [`crate::dnn::zoo::by_name`]).
    pub network: String,
    /// Input height / width.
    pub height: usize,
    pub width: usize,
    /// Device name: ZC706 | KU115 | VU9P | ZCU102.
    pub device: String,
    /// Bit width: 8 | 16.
    pub bits: u32,
    /// Batch size; 0 = explore freely (Table 4 mode).
    pub batch: usize,
    /// PSO population / iterations.
    pub population: usize,
    pub iterations: usize,
    /// RNG seed.
    pub seed: u64,
    /// Swarm-evaluation worker threads (0 = machine parallelism). Purely
    /// a wall-clock knob: results are identical at any value.
    pub threads: usize,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            network: "vgg16_conv".into(),
            height: 224,
            width: 224,
            device: "KU115".into(),
            bits: 16,
            batch: 1,
            population: 24,
            iterations: 30,
            seed: 0xD44E,
            threads: 1,
        }
    }
}

impl ExperimentConfig {
    /// Parse from TOML-subset text; unknown keys are rejected.
    pub fn from_toml(text: &str) -> anyhow::Result<Self> {
        let mut cfg = Self::default();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            anyhow::ensure!(
                !line.starts_with('['),
                "line {}: tables are not supported in experiment configs",
                lineno + 1
            );
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("line {}: expected key = value", lineno + 1))?;
            let k = k.trim();
            let v = v.trim().trim_matches('"');
            let parse_usize = |v: &str| -> anyhow::Result<usize> {
                v.parse().map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))
            };
            match k {
                "network" => cfg.network = v.to_string(),
                "height" => cfg.height = parse_usize(v)?,
                "width" => cfg.width = parse_usize(v)?,
                "device" => cfg.device = v.to_string(),
                "bits" => cfg.bits = parse_usize(v)? as u32,
                "batch" => cfg.batch = parse_usize(v)?,
                "population" => cfg.population = parse_usize(v)?,
                "iterations" => cfg.iterations = parse_usize(v)?,
                "threads" => cfg.threads = parse_usize(v)?,
                "seed" => {
                    cfg.seed = v
                        .parse()
                        .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?
                }
                other => anyhow::bail!("line {}: unknown key {other:?}", lineno + 1),
            }
        }
        Ok(cfg)
    }

    /// Load from a file.
    pub fn from_file(path: &std::path::Path) -> anyhow::Result<Self> {
        Self::from_toml(&std::fs::read_to_string(path)?)
    }

    pub fn precision(&self) -> anyhow::Result<Precision> {
        match self.bits {
            16 => Ok(Precision::Int16),
            8 => Ok(Precision::Int8),
            b => anyhow::bail!("unsupported bit width {b} (use 8 or 16)"),
        }
    }

    pub fn resolve_device(&self) -> anyhow::Result<FpgaDevice> {
        FpgaDevice::by_name(&self.device)
            .ok_or_else(|| anyhow::anyhow!("unknown device {:?}", self.device))
    }

    pub fn resolve_network(&self) -> anyhow::Result<Network> {
        let p = self.precision()?;
        crate::dnn::zoo::by_name(&self.network, self.height, self.width, p)
            .ok_or_else(|| anyhow::anyhow!("unknown network {:?}", self.network))
    }

    /// Build the explorer configuration.
    pub fn explorer(&self) -> anyhow::Result<ExplorerConfig> {
        let device = self.resolve_device()?;
        let p = self.precision()?;
        Ok(ExplorerConfig {
            dw: p,
            ww: p,
            fixed_batch: if self.batch == 0 { None } else { Some(self.batch) },
            pso: PsoParams {
                population: self.population,
                iterations: self.iterations,
                ..PsoParams::default()
            },
            seed: self.seed,
            threads: self.resolved_threads(),
            ..ExplorerConfig::new(device)
        })
    }

    /// `threads` with 0 resolved to the machine's available parallelism.
    pub fn resolved_threads(&self) -> usize {
        if self.threads == 0 {
            crate::util::parallel::default_threads()
        } else {
            self.threads
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_subset_with_defaults() {
        let c = ExperimentConfig::from_toml(
            "network = \"alexnet\"\nheight = 227 # comment\nwidth = 227\n",
        )
        .unwrap();
        assert_eq!(c.network, "alexnet");
        assert_eq!(c.height, 227);
        assert_eq!(c.device, "KU115");
        assert!(c.resolve_device().is_ok());
        assert!(c.resolve_network().is_ok());
        assert!(c.explorer().is_ok());
    }

    #[test]
    fn rejects_unknown_keys_and_tables() {
        assert!(ExperimentConfig::from_toml("bogus = 1\n").is_err());
        assert!(ExperimentConfig::from_toml("[table]\n").is_err());
        assert!(ExperimentConfig::from_toml("no_equals\n").is_err());
    }

    #[test]
    fn bad_bits_rejected() {
        let c = ExperimentConfig { bits: 12, ..Default::default() };
        assert!(c.precision().is_err());
    }

    #[test]
    fn batch_zero_means_explore() {
        let c = ExperimentConfig { batch: 0, ..Default::default() };
        assert_eq!(c.explorer().unwrap().fixed_batch, None);
    }

    #[test]
    fn unknown_network_rejected() {
        let c = ExperimentConfig { network: "nope".into(), ..Default::default() };
        assert!(c.resolve_network().is_err());
    }
}
