//! Lock-rank sanitizer: a [`Mutex`] wrapper that enforces a global
//! acquisition order at test time, plus [`lock_clean`] — poison-free
//! locking for the serving path.
//!
//! The static lint ([`crate::analysis`], rule L001) catches a guard
//! held across a *named* blocking call, but it cannot prove the absence
//! of deadlock by cyclic lock acquisition — that needs a dynamic check.
//! [`OrdMutex`] assigns every coordinator mutex a rank (see [`rank`])
//! and keeps a thread-local stack of currently-held ranks; acquiring a
//! mutex whose rank is not strictly greater than the top of the stack
//! panics with **both** acquisition sites (the held lock's and the
//! offending one's), so a single test run pinpoints the inversion. The
//! checks compile away under `cfg(not(debug_assertions))` — release
//! builds pay one plain `Mutex::lock`.
//!
//! Poison policy: both [`OrdMutex::lock`] and [`lock_clean`] recover
//! the guard from a poisoned mutex instead of panicking. A worker that
//! panicked mid-request used to poison shared serving state and cascade
//! the panic into every submitter and worker that touched the lock
//! next; the data under these locks (queue lanes, dedup tables, AIMD
//! samples) is self-healing counters-and-collections state, so serving
//! degrades by at most the one lost request instead of collapsing.
//!
//! Waiting on a [`Condvar`] releases the lock, so it must also release
//! the rank for the duration of the park — [`OrdMutex::wait`] /
//! [`OrdMutex::wait_timeout`] do exactly that (pop rank, park on the
//! inner guard, re-register on wake). This is also why the L001 lint
//! does *not* treat `Condvar::wait` as blocking-while-holding.

use std::fmt;
use std::panic::Location;
use std::sync::{Condvar, Mutex, MutexGuard, PoisonError};
use std::time::Duration;

/// Lock ranks for every coordinator mutex, in required acquisition
/// order (lower first). No current code path nests two of these, so
/// the ranks encode the *intended* order for future code: front-of-
/// pipeline state before per-stage state before settle-path state.
pub mod rank {
    /// `DedupCoalescer::inflight` — taken at the pipeline front, before
    /// any admission queue is touched.
    pub const DEDUP_INFLIGHT: u32 = 10;
    /// `AdmissionQueue::state` — the per-stage admission lock.
    pub const QUEUE_STATE: u32 = 20;
    /// `AimdWindow::samples` — settle-path latency sample buffer.
    pub const AIMD_SAMPLES: u32 = 30;
    /// `SloEngine::state` — the SLO evaluator's window/recorder books,
    /// touched only on the (off-hot-path) tick and render paths.
    pub const SLO_STATE: u32 = 40;
}

/// Lock a plain [`Mutex`], recovering the guard if a previous holder
/// panicked. Use for shared serving state whose invariants hold between
/// statements (counters, maps): one poisoned request must not cascade.
pub fn lock_clean<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

#[cfg(debug_assertions)]
mod tracking {
    use std::cell::RefCell;
    use std::panic::Location;

    struct Held {
        rank: u32,
        name: &'static str,
        id: usize,
        site: &'static Location<'static>,
    }

    thread_local! {
        static HELD: RefCell<Vec<Held>> = const { RefCell::new(Vec::new()) };
    }

    /// Register an acquisition, panicking on a rank inversion. Because
    /// every push requires a strictly greater rank than the top, the
    /// stack is always strictly increasing and checking the top alone
    /// suffices (removal of any element preserves the property).
    pub(super) fn acquire(
        rank: u32,
        name: &'static str,
        id: usize,
        site: &'static Location<'static>,
    ) {
        HELD.with(|cell| {
            let mut held = cell.borrow_mut();
            if let Some(top) = held.last() {
                if top.id == id {
                    panic!(
                        "ordlock: recursive lock of {name} (rank {rank}) at {site}; \
                         first acquired at {}",
                        top.site
                    );
                }
                if top.rank >= rank {
                    panic!(
                        "ordlock: lock-order violation: acquiring {name} (rank {rank}) at \
                         {site} while holding {} (rank {}) acquired at {}",
                        top.name, top.rank, top.site
                    );
                }
            }
            held.push(Held { rank, name, id, site });
        });
    }

    /// Unregister by mutex identity — guards may drop out of LIFO
    /// order (e.g. `drop(outer)` before `inner` falls out of scope).
    pub(super) fn release(id: usize) {
        HELD.with(|cell| {
            let mut held = cell.borrow_mut();
            if let Some(pos) = held.iter().rposition(|h| h.id == id) {
                held.remove(pos);
            }
        });
    }
}

/// A [`Mutex`] with a rank checked against a thread-local stack of held
/// locks under `debug_assertions`. See the module docs.
pub struct OrdMutex<T> {
    inner: Mutex<T>,
    rank: u32,
    name: &'static str,
}

impl<T> OrdMutex<T> {
    pub fn new(rank: u32, name: &'static str, value: T) -> Self {
        Self { inner: Mutex::new(value), rank, name }
    }

    /// The rank this mutex must be acquired at (lower = earlier).
    pub fn rank(&self) -> u32 {
        self.rank
    }

    /// Diagnostic name used in violation messages.
    pub fn name(&self) -> &'static str {
        self.name
    }

    fn id(&self) -> usize {
        self as *const Self as *const () as usize
    }

    #[cfg(debug_assertions)]
    fn note_acquire(&self, site: &'static Location<'static>) {
        tracking::acquire(self.rank, self.name, self.id(), site);
    }

    #[cfg(not(debug_assertions))]
    fn note_acquire(&self, _site: &'static Location<'static>) {}

    /// Acquire, enforcing rank order (debug) and recovering poison.
    #[track_caller]
    pub fn lock(&self) -> OrdMutexGuard<'_, T> {
        self.note_acquire(Location::caller());
        OrdMutexGuard::new(lock_clean(&self.inner), self.id())
    }

    /// `Condvar::wait` that keeps the rank stack honest: the rank is
    /// released for the duration of the park (the lock is not held) and
    /// re-registered on wake. Poison on re-acquisition is recovered.
    #[track_caller]
    pub fn wait<'a>(&'a self, cv: &Condvar, guard: OrdMutexGuard<'a, T>) -> OrdMutexGuard<'a, T> {
        let inner = guard.into_inner_guard();
        let inner = cv.wait(inner).unwrap_or_else(PoisonError::into_inner);
        self.note_acquire(Location::caller());
        OrdMutexGuard::new(inner, self.id())
    }

    /// [`Self::wait`] with a timeout; the boolean is `timed_out()`.
    #[track_caller]
    pub fn wait_timeout<'a>(
        &'a self,
        cv: &Condvar,
        guard: OrdMutexGuard<'a, T>,
        timeout: Duration,
    ) -> (OrdMutexGuard<'a, T>, bool) {
        let inner = guard.into_inner_guard();
        let (inner, result) =
            cv.wait_timeout(inner, timeout).unwrap_or_else(PoisonError::into_inner);
        self.note_acquire(Location::caller());
        (OrdMutexGuard::new(inner, self.id()), result.timed_out())
    }
}

impl<T: fmt::Debug> fmt::Debug for OrdMutex<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("OrdMutex")
            .field("name", &self.name)
            .field("rank", &self.rank)
            .field("inner", &self.inner)
            .finish()
    }
}

/// Guard for an [`OrdMutex`]; unregisters its rank on drop.
pub struct OrdMutexGuard<'a, T> {
    /// `None` only transiently, while parked in `wait`/`wait_timeout`
    /// (the inner guard has been surrendered to the condvar).
    inner: Option<MutexGuard<'a, T>>,
    #[cfg(debug_assertions)]
    id: usize,
}

impl<'a, T> OrdMutexGuard<'a, T> {
    fn new(inner: MutexGuard<'a, T>, id: usize) -> Self {
        #[cfg(not(debug_assertions))]
        let _ = id;
        Self {
            inner: Some(inner),
            #[cfg(debug_assertions)]
            id,
        }
    }

    /// Surrender the inner guard (for condvar waits), unregistering the
    /// rank. The emptied wrapper's drop is then a no-op.
    fn into_inner_guard(mut self) -> MutexGuard<'a, T> {
        let inner = self.inner.take().expect("ordlock guard already surrendered");
        #[cfg(debug_assertions)]
        tracking::release(self.id);
        inner
    }
}

impl<T> std::ops::Deref for OrdMutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_deref().expect("ordlock guard used after surrender")
    }
}

impl<T> std::ops::DerefMut for OrdMutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_deref_mut().expect("ordlock guard used after surrender")
    }
}

impl<T> Drop for OrdMutexGuard<'_, T> {
    fn drop(&mut self) {
        if self.inner.take().is_some() {
            #[cfg(debug_assertions)]
            tracking::release(self.id);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn in_rank_nesting_and_out_of_lifo_release_are_allowed() {
        let a = OrdMutex::new(1, "a", 1u32);
        let b = OrdMutex::new(2, "b", 2u32);
        let ga = a.lock();
        let gb = b.lock();
        assert_eq!((*ga, *gb), (1, 2));
        drop(ga); // release the lower rank first: must not confuse the stack
        drop(gb);
        let _ok = b.lock(); // stack is clean again
    }

    #[cfg(debug_assertions)]
    #[test]
    fn inversion_panics_with_both_acquisition_sites() {
        let a = OrdMutex::new(1, "lock-a", ());
        let b = OrdMutex::new(2, "lock-b", ());
        let err = std::thread::Builder::new()
            .name("ordlock-inversion".into())
            .spawn(move || {
                let _gb = b.lock();
                let _ga = a.lock(); // rank 1 after rank 2: inversion
            })
            .expect("spawn inversion thread")
            .join()
            .expect_err("inversion must panic");
        let msg = err.downcast_ref::<String>().expect("string panic payload").clone();
        assert!(msg.contains("lock-order violation"), "{msg}");
        assert!(msg.contains("lock-a (rank 1)"), "{msg}");
        assert!(msg.contains("lock-b (rank 2)"), "{msg}");
        // Both acquisition sites appear, file:line each.
        assert_eq!(msg.matches("ordlock.rs").count(), 2, "{msg}");
    }

    #[cfg(debug_assertions)]
    #[test]
    fn recursive_lock_panics_instead_of_deadlocking() {
        let m = OrdMutex::new(3, "recursive", ());
        let err = std::thread::Builder::new()
            .name("ordlock-recursive".into())
            .spawn(move || {
                let _g1 = m.lock();
                let _g2 = m.lock();
            })
            .expect("spawn recursion thread")
            .join()
            .expect_err("recursive lock must panic");
        let msg = err.downcast_ref::<String>().expect("string panic payload").clone();
        assert!(msg.contains("recursive lock"), "{msg}");
    }

    #[test]
    fn wait_timeout_releases_and_reacquires_the_rank() {
        let m = OrdMutex::new(5, "waiter", 0u32);
        let cv = Condvar::new();
        let g = m.lock();
        let (g, timed_out) = m.wait_timeout(&cv, g, Duration::from_millis(5));
        assert!(timed_out);
        drop(g);
        // If the wait cycle leaked a stack entry this relock would trip
        // the recursive-lock check.
        let _again = m.lock();
    }

    #[test]
    fn poisoned_ordmutex_recovers_the_guard() {
        let m = Arc::new(OrdMutex::new(7, "poisoned", vec![1, 2]));
        let m2 = m.clone();
        let joined = std::thread::Builder::new()
            .name("ordlock-poisoner".into())
            .spawn(move || {
                let _g = m2.lock();
                panic!("poison the mutex");
            })
            .expect("spawn poisoner")
            .join();
        assert!(joined.is_err());
        assert_eq!(m.lock()[0], 1, "lock recovers after a holder panicked");
    }

    #[test]
    fn lock_clean_recovers_a_poisoned_std_mutex() {
        let m = Arc::new(Mutex::new(41u32));
        let m2 = m.clone();
        let joined = std::thread::Builder::new()
            .name("lock-clean-poisoner".into())
            .spawn(move || {
                let _g = m2.lock().expect("first lock");
                panic!("poison");
            })
            .expect("spawn poisoner")
            .join();
        assert!(joined.is_err());
        assert!(m.is_poisoned());
        let mut g = lock_clean(&m);
        *g += 1;
        assert_eq!(*g, 42);
    }
}
