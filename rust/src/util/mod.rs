//! Small self-contained utilities replacing crates unavailable in the
//! offline build environment (see the note at the top of Cargo.toml).

pub mod bench;
pub mod json;
pub mod ordlock;
pub mod pace;
pub mod parallel;
pub mod proptest;
pub mod rng;

pub use ordlock::{lock_clean, OrdMutex, OrdMutexGuard};
pub use rng::Rng;
