//! Deterministic PRNG: SplitMix64 seeding + xoshiro256** core.
//!
//! Replaces `rand`/`rand_chacha` (not available offline). Quality is more
//! than sufficient for PSO perturbations and property-test generation;
//! determinism under a seed is the property the DSE tests rely on.

/// xoshiro256** generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 (handles seed = 0 fine).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform f64 in [0, 1).
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform usize in [0, n). n must be > 0.
    pub fn gen_index(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.gen_f64() * n as f64) as usize % n
    }

    /// Uniform f64 in [lo, hi).
    pub fn gen_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.gen_f64()
    }

    /// Uniform u64 in [lo, hi] inclusive.
    pub fn gen_u64_range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi >= lo);
        lo + self.next_u64() % (hi - lo + 1)
    }

    /// Random bool with probability `p` of true.
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_under_seed() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(7);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.gen_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn ranges_respected() {
        let mut r = Rng::seed_from_u64(9);
        for _ in 0..1000 {
            let x = r.gen_range(-2.0, 3.0);
            assert!((-2.0..3.0).contains(&x));
            let i = r.gen_index(10);
            assert!(i < 10);
            let u = r.gen_u64_range(5, 8);
            assert!((5..=8).contains(&u));
        }
    }

    #[test]
    fn zero_seed_not_degenerate() {
        let mut r = Rng::seed_from_u64(0);
        let a = r.next_u64();
        let b = r.next_u64();
        assert_ne!(a, 0);
        assert_ne!(a, b);
    }
}
