//! Tiny JSON **emitter** (serde_json is unavailable offline).
//!
//! Only emission is needed on the rust side (CLI `--json` output and
//! saved reports); the artifact manifest uses a line format parsed by
//! [`crate::runtime::artifacts`].

/// A JSON value builder.
#[derive(Debug, Clone)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj(fields: Vec<(&str, Json)>) -> Json {
        Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn s(v: impl Into<String>) -> Json {
        Json::Str(v.into())
    }

    pub fn n(v: f64) -> Json {
        Json::Num(v)
    }

    /// Serialize to a compact JSON string.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        out.push_str(&format!("{}", *x as i64));
                    } else {
                        out.push_str(&format!("{x}"));
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested() {
        let j = Json::obj(vec![
            ("a", Json::n(1.0)),
            ("b", Json::Arr(vec![Json::n(1.5), Json::Bool(true), Json::Null])),
            ("c", Json::s("x\"y")),
        ]);
        assert_eq!(j.render(), r#"{"a":1,"b":[1.5,true,null],"c":"x\"y"}"#);
    }

    #[test]
    fn integers_render_without_decimal() {
        assert_eq!(Json::n(42.0).render(), "42");
        assert_eq!(Json::n(0.5).render(), "0.5");
        assert_eq!(Json::n(f64::NAN).render(), "null");
    }
}
