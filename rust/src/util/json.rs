//! Tiny JSON **emitter and parser** (serde_json is unavailable offline).
//!
//! Emission serves the CLI `--json` output and saved reports; parsing
//! serves the on-disk [`crate::dse::persist`] evaluation-cache format.
//! The parser is a strict recursive-descent over the JSON grammar
//! (objects, arrays, strings with escapes, numbers, literals) — enough
//! to round-trip anything [`Json::render`] emits.

/// A JSON value builder.
#[derive(Debug, Clone)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj(fields: Vec<(&str, Json)>) -> Json {
        Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn s(v: impl Into<String>) -> Json {
        Json::Str(v.into())
    }

    pub fn n(v: f64) -> Json {
        Json::Num(v)
    }

    /// Serialize to a compact JSON string.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        out.push_str(&format!("{}", *x as i64));
                    } else {
                        out.push_str(&format!("{x}"));
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl Json {
    /// Parse a JSON document. Trailing non-whitespace is an error.
    pub fn parse(text: &str) -> anyhow::Result<Json> {
        let bytes = text.as_bytes();
        let mut p = Parser { bytes, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        anyhow::ensure!(p.pos >= bytes.len(), "trailing garbage at byte {}", p.pos);
        Ok(v)
    }

    /// Object field lookup (first match; `None` on non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items.as_slice()),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.peek() == Some(b),
            "expected {:?} at byte {}, found {:?}",
            b as char,
            self.pos,
            self.peek().map(|c| c as char)
        );
        self.pos += 1;
        Ok(())
    }

    fn literal(&mut self, word: &str, value: Json) -> anyhow::Result<Json> {
        let end = self.pos + word.len();
        anyhow::ensure!(
            end <= self.bytes.len() && &self.bytes[self.pos..end] == word.as_bytes(),
            "invalid literal at byte {}",
            self.pos
        );
        self.pos = end;
        Ok(value)
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!(
                "unexpected {:?} at byte {}",
                other.map(|c| c as char),
                self.pos
            ),
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                other => anyhow::bail!(
                    "expected ',' or '}}' at byte {}, found {:?}",
                    self.pos,
                    other.map(|c| c as char)
                ),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => anyhow::bail!(
                    "expected ',' or ']' at byte {}, found {:?}",
                    self.pos,
                    other.map(|c| c as char)
                ),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => anyhow::bail!("unterminated string at byte {}", self.pos),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self
                        .peek()
                        .ok_or_else(|| anyhow::anyhow!("dangling escape at byte {}", self.pos))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'u' => {
                            let end = self.pos + 4;
                            anyhow::ensure!(
                                end <= self.bytes.len(),
                                "truncated \\u escape at byte {}",
                                self.pos
                            );
                            let hex = std::str::from_utf8(&self.bytes[self.pos..end])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            // BMP only — all this crate ever emits.
                            out.push(
                                char::from_u32(code).ok_or_else(|| {
                                    anyhow::anyhow!("invalid \\u{hex} escape")
                                })?,
                            );
                            self.pos = end;
                        }
                        other => anyhow::bail!("unknown escape \\{}", other as char),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (multi-byte safe).
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        let v: f64 = text
            .parse()
            .map_err(|e| anyhow::anyhow!("bad number {text:?} at byte {start}: {e}"))?;
        Ok(Json::Num(v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested() {
        let j = Json::obj(vec![
            ("a", Json::n(1.0)),
            ("b", Json::Arr(vec![Json::n(1.5), Json::Bool(true), Json::Null])),
            ("c", Json::s("x\"y")),
        ]);
        assert_eq!(j.render(), r#"{"a":1,"b":[1.5,true,null],"c":"x\"y"}"#);
    }

    #[test]
    fn integers_render_without_decimal() {
        assert_eq!(Json::n(42.0).render(), "42");
        assert_eq!(Json::n(0.5).render(), "0.5");
        assert_eq!(Json::n(f64::NAN).render(), "null");
    }

    #[test]
    fn parse_roundtrips_render() {
        let j = Json::obj(vec![
            ("a", Json::n(1.0)),
            ("b", Json::Arr(vec![Json::n(1.5), Json::Bool(true), Json::Null])),
            ("c", Json::s("x\"y\nz\\w")),
            ("d", Json::obj(vec![("nested", Json::n(-3.25))])),
            ("e", Json::Arr(vec![])),
            ("f", Json::obj(vec![])),
        ]);
        let text = j.render();
        let back = Json::parse(&text).expect("parse");
        assert_eq!(back.render(), text, "render∘parse∘render is identity");
        assert_eq!(back.get("a").and_then(Json::as_f64), Some(1.0));
        assert_eq!(back.get("c").and_then(Json::as_str), Some("x\"y\nz\\w"));
        assert_eq!(back.get("b").and_then(Json::as_arr).map(|a| a.len()), Some(3));
        assert_eq!(
            back.get("d").and_then(|d| d.get("nested")).and_then(Json::as_f64),
            Some(-3.25)
        );
        assert!(back.get("missing").is_none());
    }

    #[test]
    fn parse_accepts_whitespace_and_escapes() {
        let j = Json::parse(" { \"k\" : [ 1 , \"\\u0041\\t\" , false ] } ").unwrap();
        let arr = j.get("k").and_then(Json::as_arr).unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[1].as_str(), Some("A\t"));
        assert_eq!(arr[2].as_bool(), Some(false));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":1} tail").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("01a").is_err());
    }

    #[test]
    fn parse_numbers_exact() {
        assert_eq!(Json::parse("-0.5").unwrap().as_f64(), Some(-0.5));
        assert_eq!(Json::parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(Json::parse("42").unwrap().as_f64(), Some(42.0));
    }
}
