//! Deterministic fork-join parallelism over slices (rayon is unavailable
//! offline; `std::thread::scope` is all the DSE hot path needs).
//!
//! [`parallel_map`] preserves input order in its output regardless of the
//! worker count or schedule, so any caller that combines results **by
//! index** is bit-identical across thread counts — the property the
//! parallel PSO and the portfolio explorer are built on. Workers only
//! ever determine *when* an element is computed, never *which value* it
//! produces or *where* it lands.
//!
//! Two schedules are available (see [`Schedule`]):
//!
//! * **Chunked** — one contiguous chunk per worker, fixed up front. Zero
//!   coordination on the hot path, but a skewed workload (one expensive
//!   chunk) leaves the other workers idle.
//! * **WorkStealing** — each worker owns a deque of contiguous indices;
//!   it pops its own front (preserving locality) and, when empty, steals
//!   from the *back* of a victim's deque. Skewed items (e.g. one
//!   portfolio scenario or shard segment that dwarfs the rest) no longer
//!   serialize the pool. This is the default; set
//!   `DNNEXPLORER_SCHEDULE=chunked` to A/B against the old path
//!   (`benches/shard_dse.rs` does exactly that).

use std::collections::VecDeque;
use std::sync::Mutex;

/// How [`parallel_map`] distributes items over workers. Purely a
/// wall-clock knob: both schedules produce identical output vectors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Schedule {
    /// Fixed contiguous chunks, one per worker (the historical path).
    Chunked,
    /// Per-worker deques with back-stealing (the default).
    WorkStealing,
}

/// The process-wide default schedule: work-stealing, unless the
/// `DNNEXPLORER_SCHEDULE=chunked` environment switch asks for the old
/// chunked path (read once, for A/B benching).
pub fn default_schedule() -> Schedule {
    static CHUNKED: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    let chunked = *CHUNKED.get_or_init(|| {
        std::env::var("DNNEXPLORER_SCHEDULE")
            .map(|v| v.eq_ignore_ascii_case("chunked"))
            .unwrap_or(false)
    });
    if chunked {
        Schedule::Chunked
    } else {
        Schedule::WorkStealing
    }
}

/// Map `f` over `items`, using up to `threads` OS threads, returning the
/// results in input order. Uses [`default_schedule`].
///
/// `threads <= 1` (or a short input) runs inline with no thread spawn at
/// all, so the sequential path is literally the `Iterator::map` loop.
/// Panics in `f` propagate to the caller.
pub fn parallel_map<T, U, F>(items: &[T], threads: usize, f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> U + Sync,
{
    parallel_map_with(items, threads, default_schedule(), f)
}

/// [`parallel_map`] with an explicit [`Schedule`] (A/B benching and the
/// callers that know their workload shape).
pub fn parallel_map_with<T, U, F>(items: &[T], threads: usize, schedule: Schedule, f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> U + Sync,
{
    let n = items.len();
    let workers = threads.max(1).min(n.max(1));
    if workers == 1 {
        return items.iter().map(f).collect();
    }
    match schedule {
        Schedule::Chunked => chunked_map(items, workers, f),
        Schedule::WorkStealing => stealing_map(items, workers, f),
    }
}

/// Contiguous chunks, one per worker; chunk boundaries depend only on
/// (n, workers), and results are re-joined in chunk order. The first
/// chunk runs on the calling thread — one fewer spawn, and the
/// caller does useful work instead of blocking in join (this keeps
/// per-call overhead low even when the work units are cheap, e.g.
/// swarm batches against a warm EvalCache).
fn chunked_map<T, U, F>(items: &[T], workers: usize, f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> U + Sync,
{
    let n = items.len();
    let chunk = n.div_ceil(workers);
    let fref = &f;
    let mut out = Vec::with_capacity(n);
    std::thread::scope(|scope| {
        let mut chunks = items.chunks(chunk);
        let first = chunks.next().unwrap_or(&[]);
        let handles: Vec<_> = chunks
            .map(|part| scope.spawn(move || part.iter().map(fref).collect::<Vec<U>>()))
            .collect();
        out.extend(first.iter().map(fref));
        for h in handles {
            out.extend(h.join().expect("parallel_map worker panicked"));
        }
    });
    out
}

/// Work-stealing: worker `w` seeds its deque with the same contiguous
/// block the chunked schedule would give it (locality), pops its own
/// **front**, and steals from the **back** of the next non-empty victim
/// when dry. Each index is removed from exactly one deque exactly once,
/// and every result carries its index, so the merged output is in input
/// order no matter who computed what.
fn stealing_map<T, U, F>(items: &[T], workers: usize, f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> U + Sync,
{
    let n = items.len();
    let chunk = n.div_ceil(workers);
    let deques: Vec<Mutex<VecDeque<usize>>> = (0..workers)
        .map(|w| {
            let lo = (w * chunk).min(n);
            let hi = ((w + 1) * chunk).min(n);
            Mutex::new((lo..hi).collect())
        })
        .collect();
    let deques = &deques;
    let fref = &f;

    let run_worker = move |w: usize| -> Vec<(usize, U)> {
        let mut local: Vec<(usize, U)> = Vec::new();
        loop {
            // Own work first (front: input order, warm caches)...
            let idx = {
                let mut own = deques[w].lock().expect("steal deque poisoned");
                own.pop_front()
            };
            let idx = match idx {
                Some(i) => Some(i),
                // ...then steal from the back of the first non-empty
                // victim, scanning away from ourselves so contention
                // spreads instead of piling on worker 0.
                None => (1..workers).find_map(|d| {
                    let v = (w + d) % workers;
                    deques[v].lock().expect("steal deque poisoned").pop_back()
                }),
            };
            match idx {
                Some(i) => local.push((i, fref(&items[i]))),
                None => break, // every deque empty: all items claimed
            }
        }
        local
    };
    let run_worker = &run_worker;

    let mut pairs: Vec<(usize, U)> = Vec::with_capacity(n);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (1..workers).map(|w| scope.spawn(move || run_worker(w))).collect();
        pairs.extend(run_worker(0));
        for h in handles {
            pairs.extend(h.join().expect("parallel_map worker panicked"));
        }
    });
    // Deterministic index-order reduction: place each result at its
    // input slot (every index appears exactly once).
    let mut slots: Vec<Option<U>> = (0..n).map(|_| None).collect();
    for (i, v) in pairs {
        debug_assert!(slots[i].is_none(), "index {i} computed twice");
        slots[i] = Some(v);
    }
    slots
        .into_iter()
        .enumerate()
        .map(|(i, v)| v.unwrap_or_else(|| panic!("index {i} never computed")))
        .collect()
}

/// A sensible default worker count: the machine's available parallelism,
/// floored at 1 (used by CLI `--threads 0`).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_at_every_thread_count_and_schedule() {
        let items: Vec<u64> = (0..103).collect();
        let expect: Vec<u64> = items.iter().map(|x| x * x).collect();
        for schedule in [Schedule::Chunked, Schedule::WorkStealing] {
            for threads in [1, 2, 3, 8, 64] {
                let got = parallel_map_with(&items, threads, schedule, |x| x * x);
                assert_eq!(got, expect, "threads={threads} schedule={schedule:?}");
            }
        }
    }

    #[test]
    fn empty_and_singleton() {
        let empty: Vec<u32> = Vec::new();
        for schedule in [Schedule::Chunked, Schedule::WorkStealing] {
            assert!(parallel_map_with(&empty, 8, schedule, |x| *x).is_empty());
            assert_eq!(parallel_map_with(&[7u32], 8, schedule, |x| x + 1), vec![8]);
        }
    }

    #[test]
    fn actually_runs_concurrently() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::time::Duration;
        // Two workers sleeping in parallel must overlap: peak in-flight
        // count reaches 2 with 2+ threads on any multi-core scheduler;
        // with threads=1 it cannot exceed 1.
        let in_flight = AtomicUsize::new(0);
        let peak = AtomicUsize::new(0);
        let items = [0u8; 4];
        parallel_map(&items, 4, |_| {
            let cur = in_flight.fetch_add(1, Ordering::SeqCst) + 1;
            peak.fetch_max(cur, Ordering::SeqCst);
            std::thread::sleep(Duration::from_millis(20));
            in_flight.fetch_sub(1, Ordering::SeqCst);
        });
        assert!(peak.load(Ordering::SeqCst) >= 1);
        let seq_peak = AtomicUsize::new(0);
        let seq_flight = AtomicUsize::new(0);
        parallel_map(&items, 1, |_| {
            let cur = seq_flight.fetch_add(1, Ordering::SeqCst) + 1;
            seq_peak.fetch_max(cur, Ordering::SeqCst);
            seq_flight.fetch_sub(1, Ordering::SeqCst);
        });
        assert_eq!(seq_peak.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn stealing_rebalances_a_skewed_head() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::time::Duration;
        // Item 0 is 50x the rest. Under work-stealing with 2 workers the
        // tail items migrate to the idle worker, so the count of items
        // executed while item 0 is still running must be > 0 — i.e. the
        // pool did not serialize behind the skewed chunk.
        let overlapped = AtomicUsize::new(0);
        let busy = AtomicUsize::new(0);
        let items: Vec<usize> = (0..16).collect();
        let out = parallel_map_with(&items, 2, Schedule::WorkStealing, |&i| {
            if i == 0 {
                busy.store(1, Ordering::SeqCst);
                std::thread::sleep(Duration::from_millis(100));
                busy.store(0, Ordering::SeqCst);
            } else {
                std::thread::sleep(Duration::from_millis(2));
                if busy.load(Ordering::SeqCst) == 1 {
                    overlapped.fetch_add(1, Ordering::SeqCst);
                }
            }
            i * 2
        });
        assert_eq!(out, (0..16).map(|i| i * 2).collect::<Vec<_>>());
        assert!(
            overlapped.load(Ordering::SeqCst) > 0,
            "no overlap: the pool serialized behind the skewed item"
        );
    }

    #[test]
    fn schedules_agree_on_nontrivial_workload() {
        let items: Vec<u64> = (0..257).map(|i| i * 31 + 7).collect();
        let a = parallel_map_with(&items, 5, Schedule::Chunked, |x| x.wrapping_mul(*x));
        let b = parallel_map_with(&items, 5, Schedule::WorkStealing, |x| x.wrapping_mul(*x));
        assert_eq!(a, b);
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
        // The default schedule resolves without panicking either way.
        let _ = default_schedule();
    }
}
