//! Deterministic fork-join parallelism over slices (rayon is unavailable
//! offline; `std::thread::scope` is all the DSE hot path needs).
//!
//! [`parallel_map`] preserves input order in its output regardless of the
//! worker count, so any caller that combines results **by index** is
//! bit-identical across thread counts — the property the parallel PSO
//! and the portfolio explorer are built on. Workers only ever determine
//! *when* an element is computed, never *which value* it produces or
//! *where* it lands.

/// Map `f` over `items`, using up to `threads` OS threads, returning the
/// results in input order.
///
/// `threads <= 1` (or a short input) runs inline with no thread spawn at
/// all, so the sequential path is literally the `Iterator::map` loop.
/// Panics in `f` propagate to the caller.
pub fn parallel_map<T, U, F>(items: &[T], threads: usize, f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> U + Sync,
{
    let n = items.len();
    let workers = threads.max(1).min(n.max(1));
    if workers == 1 {
        return items.iter().map(f).collect();
    }
    // Contiguous chunks, one per worker; chunk boundaries depend only on
    // (n, workers), and results are re-joined in chunk order. The first
    // chunk runs on the calling thread — one fewer spawn, and the
    // caller does useful work instead of blocking in join (this keeps
    // per-call overhead low even when the work units are cheap, e.g.
    // swarm batches against a warm EvalCache).
    let chunk = n.div_ceil(workers);
    let fref = &f;
    let mut out = Vec::with_capacity(n);
    std::thread::scope(|scope| {
        let mut chunks = items.chunks(chunk);
        let first = chunks.next().unwrap_or(&[]);
        let handles: Vec<_> = chunks
            .map(|part| scope.spawn(move || part.iter().map(fref).collect::<Vec<U>>()))
            .collect();
        out.extend(first.iter().map(fref));
        for h in handles {
            out.extend(h.join().expect("parallel_map worker panicked"));
        }
    });
    out
}

/// A sensible default worker count: the machine's available parallelism,
/// floored at 1 (used by CLI `--threads 0`).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_at_every_thread_count() {
        let items: Vec<u64> = (0..103).collect();
        let expect: Vec<u64> = items.iter().map(|x| x * x).collect();
        for threads in [1, 2, 3, 8, 64] {
            let got = parallel_map(&items, threads, |x| x * x);
            assert_eq!(got, expect, "threads={threads}");
        }
    }

    #[test]
    fn empty_and_singleton() {
        let empty: Vec<u32> = Vec::new();
        assert!(parallel_map(&empty, 8, |x| *x).is_empty());
        assert_eq!(parallel_map(&[7u32], 8, |x| x + 1), vec![8]);
    }

    #[test]
    fn actually_runs_concurrently() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::time::Duration;
        // Two workers sleeping in parallel must overlap: peak in-flight
        // count reaches 2 with 2+ threads on any multi-core scheduler;
        // with threads=1 it cannot exceed 1.
        let in_flight = AtomicUsize::new(0);
        let peak = AtomicUsize::new(0);
        let items = [0u8; 4];
        parallel_map(&items, 4, |_| {
            let cur = in_flight.fetch_add(1, Ordering::SeqCst) + 1;
            peak.fetch_max(cur, Ordering::SeqCst);
            std::thread::sleep(Duration::from_millis(20));
            in_flight.fetch_sub(1, Ordering::SeqCst);
        });
        assert!(peak.load(Ordering::SeqCst) >= 1);
        let seq_peak = AtomicUsize::new(0);
        let seq_flight = AtomicUsize::new(0);
        parallel_map(&items, 1, |_| {
            let cur = seq_flight.fetch_add(1, Ordering::SeqCst) + 1;
            seq_peak.fetch_max(cur, Ordering::SeqCst);
            seq_flight.fetch_sub(1, Ordering::SeqCst);
        });
        assert_eq!(seq_peak.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
    }
}
