//! Hybrid sleep/spin pacing for open-loop load generation.
//!
//! `std::thread::sleep` to an absolute target rounds up to scheduler
//! granularity (typically 50µs–1ms, worse under load), so a bench
//! pacing arrivals purely by sleeping issues frames in lumps that
//! masquerade as bursts — exactly the artifact a trace-driven harness
//! must not inject. [`Pacer`] sleeps coarsely to within
//! `spin_threshold` of the target, then spins the remainder on
//! [`std::hint::spin_loop`]. It never releases early: lateness is
//! bounded by preemption, earliness by construction is zero.

use std::time::{Duration, Instant};

/// Default handover point from coarse sleep to spin. Large enough to
/// cover common timer slop, small enough that the busy-wait cost per
/// event stays in the hundreds of microseconds.
pub const DEFAULT_SPIN_THRESHOLD: Duration = Duration::from_micros(200);

/// Paces a sequence of events against a fixed epoch.
///
/// All targets are offsets from the epoch, so accumulated lateness on
/// one event never skews later ones (open-loop, not closed-loop).
#[derive(Debug, Clone, Copy)]
pub struct Pacer {
    epoch: Instant,
    spin_threshold: Duration,
}

impl Pacer {
    /// A pacer whose offsets are measured from `epoch`.
    pub fn new(epoch: Instant) -> Self {
        Self { epoch, spin_threshold: DEFAULT_SPIN_THRESHOLD }
    }

    /// Like [`Pacer::new`] with an explicit sleep→spin handover point
    /// (`Duration::ZERO` spins the whole wait; useful in tests).
    pub fn with_spin_threshold(epoch: Instant, spin_threshold: Duration) -> Self {
        Self { epoch, spin_threshold }
    }

    /// The epoch offsets are measured from.
    pub fn epoch(&self) -> Instant {
        self.epoch
    }

    /// Block until `offset` past the epoch. Returns immediately if the
    /// target is already in the past. Guaranteed never to return early.
    pub fn pace_until(&self, offset: Duration) {
        let target = self.epoch + offset;
        // Coarse phase: sleep until spin_threshold short of the target.
        let coarse = target - self.spin_threshold;
        if let Some(d) = coarse.checked_duration_since(Instant::now()) {
            std::thread::sleep(d);
        }
        // Fine phase: spin out the remainder.
        while Instant::now() < target {
            std::hint::spin_loop();
        }
    }

    /// Pace the `i`-th event of a uniform `rate_hz` stream.
    pub fn pace_index(&self, i: usize, rate_hz: f64) {
        self.pace_until(Duration::from_secs_f64(i as f64 / rate_hz));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The contract the serve-bench paths rely on: no event fires
    /// early, and the pacer holds inter-arrival error well under the
    /// millisecond-scale lumps plain `sleep` produces. Bounds are
    /// deliberately loose (shared CI machines preempt), but tight
    /// enough that a regression back to sleep-only pacing — where
    /// most events land a full timer quantum late — fails.
    #[test]
    fn paced_events_are_never_early_and_mostly_on_time() {
        let events = 40usize;
        let rate_hz = 2_000.0; // 500us apart
        let pacer = Pacer::new(Instant::now());
        let mut lateness_us = Vec::with_capacity(events);
        for i in 0..events {
            pacer.pace_index(i, rate_hz);
            let target = Duration::from_secs_f64(i as f64 / rate_hz);
            let actual = pacer.epoch().elapsed();
            assert!(actual >= target, "event {i} fired early: {actual:?} < {target:?}");
            lateness_us.push((actual - target).as_micros() as u64);
        }
        let within = lateness_us.iter().filter(|&&l| l <= 300).count();
        assert!(
            within * 10 >= events * 7,
            "only {within}/{events} events within 300us of target (lateness {lateness_us:?})"
        );
    }

    #[test]
    fn past_targets_return_immediately() {
        let pacer = Pacer::new(Instant::now() - Duration::from_secs(1));
        let t = Instant::now();
        pacer.pace_until(Duration::from_millis(1));
        assert!(t.elapsed() < Duration::from_millis(100));
    }
}
