//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! Benches under `rust/benches/` are plain `main()` binaries
//! (`harness = false`) using [`bench`] for timed sections: warmup, then
//! repeated timed runs, reporting min/mean/p50/p95.

use std::time::Instant;

/// Timing summary of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub min_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
}

impl BenchStats {
    pub fn report(&self) -> String {
        fn fmt(s: f64) -> String {
            if s >= 1.0 {
                format!("{s:.3}s")
            } else if s >= 1e-3 {
                format!("{:.3}ms", s * 1e3)
            } else {
                format!("{:.1}us", s * 1e6)
            }
        }
        format!(
            "bench {:<40} iters={:<4} mean={} min={} p50={} p95={}",
            self.name,
            self.iters,
            fmt(self.mean_s),
            fmt(self.min_s),
            fmt(self.p50_s),
            fmt(self.p95_s),
        )
    }
}

/// Run `f` with `warmup` unmeasured and `iters` measured iterations.
/// The closure's return value is black-boxed to keep the work alive.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchStats {
    for _ in 0..warmup {
        black_box(f());
    }
    let mut samples = Vec::with_capacity(iters.max(1));
    for _ in 0..iters.max(1) {
        let t = Instant::now();
        black_box(f());
        samples.push(t.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let stats = BenchStats {
        name: name.to_string(),
        iters: n,
        mean_s: samples.iter().sum::<f64>() / n as f64,
        min_s: samples[0],
        p50_s: samples[n / 2],
        p95_s: samples[(n * 95 / 100).min(n - 1)],
    };
    println!("{}", stats.report());
    stats
}

/// Optimization barrier (std::hint::black_box).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Quick-mode switch for bench binaries: `DNNEXPLORER_BENCH_FULL=1` runs
/// paper-scale effort; default keeps bench runtime modest.
pub fn full_mode() -> bool {
    std::env::var("DNNEXPLORER_BENCH_FULL").map(|v| v == "1").unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let s = bench("noop", 1, 5, || 1 + 1);
        assert_eq!(s.iters, 5);
        assert!(s.min_s <= s.mean_s);
        assert!(s.p50_s <= s.p95_s + 1e-12);
        assert!(s.report().contains("noop"));
    }
}
