//! Generic-structure analytical model (paper §6.2, Eq. 5–13).
//!
//! A reusable `CPF_g × KPF_g` MAC array processes layers `SP+1..N` in a
//! recurrent manner. Two on-chip buffer allocation strategies are
//! modeled (paper §5.3.2):
//!
//! 1. **FmAccumInBram** — BRAM holds the feature-map + accumulation
//!    buffers; the weight buffer lives in LUTs (Xilinx DPU style).
//! 2. **AllInBram** — BRAM holds all buffers (VTA / HybridDNN style),
//!    enabling the weight-stationary dataflow.
//!
//! Under strategy 2 each layer independently picks the better of the
//! input-stationary (IS) and weight-stationary (WS) dataflows.


use crate::dnn::{Layer, Precision};
use crate::fpga::resource::{bram18k_for, ResourceBudget};

/// On-chip buffer allocation strategy (paper §5.3.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BufferStrategy {
    /// Strategy 1: BRAM → feature-map + accumulation buffers; LUT → weights.
    FmAccumInBram,
    /// Strategy 2: BRAM → all buffers.
    AllInBram,
}

/// Dataflow of the generic structure (strategy 2 only offers the choice).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dataflow {
    InputStationary,
    WeightStationary,
}

/// Generic-structure hardware configuration.
#[derive(Debug, Clone)]
pub struct GenericConfig {
    pub cpf: usize,
    pub kpf: usize,
    pub dw: Precision,
    pub ww: Precision,
    pub strategy: BufferStrategy,
    pub freq_mhz: f64,
    /// Feature-map buffer capacity, bits.
    pub cap_fm_bits: f64,
    /// Accumulation buffer capacity, bits.
    pub cap_accum_bits: f64,
    /// Weight buffer capacity, bits (BRAM under strategy 2; LUT-RAM under
    /// strategy 1, still finite).
    pub cap_w_bits: f64,
}

impl GenericConfig {
    /// Build a config that fills a BRAM18K block budget with the
    /// strategy's canonical split.
    ///
    /// * Strategy 1: accum 1/8, feature maps 7/8 of BRAM bits; weight
    ///   buffer gets a LUT-RAM allowance (256 Kb — typical distributed-RAM
    ///   budget of the mid-range parts).
    /// * Strategy 2: weights 1/2, feature maps 3/8, accum 1/8.
    pub fn with_budget(
        cpf: usize,
        kpf: usize,
        dw: Precision,
        ww: Precision,
        strategy: BufferStrategy,
        freq_mhz: f64,
        bram18k_budget: f64,
    ) -> Self {
        // 85% fill target: block-granularity rounding and port-width
        // padding must not push the realized usage past the budget.
        let bits = bram18k_budget * 18.0 * 1024.0 * 0.85;
        let (cap_fm, cap_accum, cap_w) = match strategy {
            BufferStrategy::FmAccumInBram => {
                (bits * 7.0 / 8.0, bits / 8.0, 256.0 * 1024.0)
            }
            BufferStrategy::AllInBram => (bits * 3.0 / 8.0, bits / 8.0, bits / 2.0),
        };
        Self {
            cpf,
            kpf,
            dw,
            ww,
            strategy,
            freq_mhz,
            cap_fm_bits: cap_fm,
            cap_accum_bits: cap_accum,
            cap_w_bits: cap_w,
        }
    }

    /// Resource usage of this configuration.
    pub fn resources(&self) -> ResourceBudget {
        let dsp = (self.cpf * self.kpf) as f64 * self.ww.dsp_per_mac();
        let fm_port = (self.cpf as f64 * self.dw.bits() as f64).max(18.0);
        let acc_port = (self.kpf as f64 * self.dw.bits() as f64).max(18.0);
        let mut bram = bram18k_for(self.cap_fm_bits, fm_port)
            + bram18k_for(self.cap_accum_bits, acc_port);
        if self.strategy == BufferStrategy::AllInBram {
            let w_port = ((self.cpf * self.kpf) as f64 * self.ww.bits() as f64).min(4608.0);
            bram += bram18k_for(self.cap_w_bits, w_port);
        }
        ResourceBudget::new(dsp, bram, 0.0)
    }
}

/// Per-layer latency breakdown.
#[derive(Debug, Clone)]
pub struct LayerLatency {
    /// Eq. 6 compute term, seconds (one frame).
    pub comp_s: f64,
    /// One weight-load pass at the weight bandwidth share, seconds.
    pub w_s: f64,
    /// Input / output feature-map swap terms, seconds (zero when the maps
    /// are on-chip resident).
    pub ifm_s: f64,
    pub ofm_s: f64,
    /// Eq. 5 feature-map group count.
    pub g_fm: f64,
    /// Eq. 12 weight group count (WS only; 1 otherwise).
    pub g_w: f64,
    /// Chosen dataflow.
    pub dataflow: Dataflow,
    /// Eq. 11/13 overall per-frame latency, seconds.
    pub total_s: f64,
    /// Whether the layer's feature maps fit on-chip (no DRAM swap).
    pub fm_resident: bool,
}

/// Whole generic-structure estimate over its layer range.
#[derive(Debug, Clone)]
pub struct GenericEstimate {
    pub layers: Vec<LayerLatency>,
    /// Steady-state period to process one batch, seconds.
    pub period_s: f64,
    pub throughput_fps: f64,
    pub gops: f64,
    pub resources: ResourceBudget,
}

/// Eq. 5: feature-map group count from the accumulation-buffer capacity
/// (ping-pong halved).
fn group_fm(l: &Layer, dw: Precision, cap_accum_bits: f64) -> f64 {
    let ofm_bits = l.output.elems() as f64 * dw.bits() as f64;
    (ofm_bits / (cap_accum_bits / 2.0)).ceil().max(1.0)
}

/// Eq. 12: weight group count from the weight-buffer capacity.
fn group_w(l: &Layer, ww: Precision, cap_w_bits: f64) -> f64 {
    let w_bits = l.weights() as f64 * ww.bits() as f64;
    (w_bits / (cap_w_bits / 2.0)).ceil().max(1.0)
}

/// Latency of one layer on the generic structure (per frame), given the
/// structure's bandwidth allocation `bw_gbps` and a batch size for weight
/// amortization.
pub fn layer_latency(l: &Layer, cfg: &GenericConfig, bw_gbps: f64, batch: usize) -> LayerLatency {
    let freq = cfg.freq_mhz * 1e6;
    let batch = batch.max(1) as f64;
    // Effective parallelism: grouped/depthwise layers cannot fill CPF
    // beyond their per-group input depth; tiny K cannot fill KPF.
    let eff_cpf = (l.input.c as f64 / l.groups() as f64).min(cfg.cpf as f64).max(1.0);
    let eff_kpf = (l.output.c as f64).min(cfg.kpf as f64).max(1.0);
    let comp_s = l.macs() as f64 / (eff_cpf * eff_kpf * freq);

    let g_fm = group_fm(l, cfg.dw, cfg.cap_accum_bits);
    let w_bytes = l.weight_bytes(cfg.ww);
    let ifm_bytes = l.ifm_bytes(cfg.dw);
    let ofm_bytes = l.ofm_bytes(cfg.dw);

    // Residency: input and output maps both fit in ping-pong halves of the
    // fm buffer → no DRAM swap for activations (Eq. 11 degenerates to Eq. 8).
    let fm_resident = (ifm_bytes + ofm_bytes) * 8.0 <= cfg.cap_fm_bits / 1.0
        && ifm_bytes * 8.0 <= cfg.cap_fm_bits / 2.0
        && ofm_bytes * 8.0 <= cfg.cap_fm_bits / 2.0;

    let bw = bw_gbps * 1e9;

    // Candidate 1: input-stationary (Eq. 11). Weight traffic is fetched
    // G_fm times per frame, amortized over the batch (the same weight
    // group serves every frame of the batch).
    let is_lat = {
        let traffic_w = w_bytes * g_fm / batch;
        let (traffic_i, traffic_o) = if fm_resident {
            (0.0, 0.0)
        } else {
            (ifm_bytes, ofm_bytes)
        };
        let total_traffic = traffic_w + traffic_i + traffic_o;
        if total_traffic <= 0.0 || bw <= 0.0 {
            (comp_s, traffic_w / bw.max(1.0), 0.0, 0.0, comp_s)
        } else {
            // Proportional bandwidth split across the three streams
            // (paper §6.2.1: BW divided into BW_w / BW_ifm / BW_ofm).
            let l_w = total_traffic / bw * (traffic_w / total_traffic).max(0.0);
            let l_i = total_traffic / bw * (traffic_i / total_traffic).max(0.0);
            let l_o = total_traffic / bw * (traffic_o / total_traffic).max(0.0);
            let mem = total_traffic / bw;
            (comp_s, l_w, l_i, l_o, comp_s.max(mem))
        }
    };

    // Candidate 2: weight-stationary (Eq. 13), strategy 2 only.
    let ws_lat = if cfg.strategy == BufferStrategy::AllInBram {
        let g_w = group_w(l, cfg.ww, cfg.cap_w_bits);
        let traffic_w = w_bytes / batch; // loaded once per batch
        let (traffic_i, traffic_o) = if fm_resident && g_w <= 1.0 {
            (0.0, 0.0)
        } else {
            (ifm_bytes * g_w, ofm_bytes * g_w)
        };
        let total_traffic = traffic_w + traffic_i + traffic_o;
        let mem = if bw > 0.0 { total_traffic / bw } else { f64::INFINITY };
        Some((comp_s.max(mem), g_w, traffic_w, traffic_i, traffic_o, mem))
    } else {
        None
    };

    let (comp_s, w_s, ifm_s, ofm_s, total_is) = is_lat;
    match ws_lat {
        Some((total_ws, g_w, tw, ti, to, mem)) if total_ws < total_is => {
            let split = |t: f64| {
                let tt = tw + ti + to;
                if tt > 0.0 {
                    mem * t / tt
                } else {
                    0.0
                }
            };
            LayerLatency {
                comp_s,
                w_s: split(tw),
                ifm_s: split(ti),
                ofm_s: split(to),
                g_fm,
                g_w,
                dataflow: Dataflow::WeightStationary,
                total_s: total_ws,
                fm_resident,
            }
        }
        _ => LayerLatency {
            comp_s,
            w_s,
            ifm_s,
            ofm_s,
            g_fm,
            g_w: 1.0,
            dataflow: Dataflow::InputStationary,
            total_s: total_is,
            fm_resident,
        },
    }
}

/// Estimate the generic structure over a slice of layers.
pub fn estimate(
    layers: &[&Layer],
    cfg: &GenericConfig,
    bw_gbps: f64,
    batch: usize,
) -> GenericEstimate {
    let batch_f = batch.max(1) as f64;
    let details: Vec<LayerLatency> = layers
        .iter()
        .map(|l| layer_latency(l, cfg, bw_gbps, batch))
        .collect();
    // The generic unit is sequential: the batch period is the sum over
    // layers of batch-scaled compute/fm terms vs once-per-batch weights.
    let period_s: f64 = details
        .iter()
        .map(|d| {
            let mem_per_batch = (d.w_s + d.ifm_s + d.ofm_s) * batch_f;
            (d.comp_s * batch_f).max(mem_per_batch)
        })
        .sum();
    let ops: f64 = layers.iter().map(|l| l.ops() as f64).sum();
    let throughput_fps = if period_s > 0.0 { batch_f / period_s } else { 0.0 };
    let mut resources = cfg.resources();
    resources.bw_gbps = bw_gbps;
    GenericEstimate {
        layers: details,
        period_s,
        throughput_fps,
        gops: throughput_fps * ops / 1e9,
        resources,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::layer::{conv_out_dim, LayerKind, TensorShape};

    fn conv_layer(c: usize, hw: usize, k: usize, kern: usize) -> Layer {
        let input = TensorShape::new(c, hw, hw);
        let o = conv_out_dim(hw, kern, 1, kern / 2);
        Layer {
            name: "t".into(),
            kind: LayerKind::Conv {
                kernel: kern,
                kernel_w: kern,
                stride: 1,
                pad: kern / 2,
                groups: 1,
            },
            input,
            output: TensorShape::new(k, o, o),
            precision: Precision::Int16,
        }
    }

    fn cfg(strategy: BufferStrategy) -> GenericConfig {
        GenericConfig::with_budget(
            32,
            64,
            Precision::Int16,
            Precision::Int16,
            strategy,
            200.0,
            1500.0,
        )
    }

    #[test]
    fn eq6_compute_latency() {
        let l = conv_layer(64, 56, 64, 3);
        let c = cfg(BufferStrategy::FmAccumInBram);
        let d = layer_latency(&l, &c, 1000.0, 1);
        let expect = l.macs() as f64 / (32.0 * 64.0 * 200e6);
        assert!((d.comp_s - expect).abs() / expect < 1e-12);
    }

    #[test]
    fn compute_bound_with_ample_bandwidth() {
        let l = conv_layer(256, 56, 256, 3);
        let c = cfg(BufferStrategy::FmAccumInBram);
        let d = layer_latency(&l, &c, 10_000.0, 1);
        assert!((d.total_s - d.comp_s).abs() / d.comp_s < 1e-6);
    }

    #[test]
    fn memory_bound_with_scarce_bandwidth() {
        // 1x1 conv: low CTC; tiny bandwidth must dominate.
        let l = conv_layer(512, 14, 512, 1);
        let c = cfg(BufferStrategy::FmAccumInBram);
        let d = layer_latency(&l, &c, 0.1, 1);
        assert!(d.total_s > d.comp_s * 2.0, "mem {} comp {}", d.total_s, d.comp_s);
    }

    #[test]
    fn batch_amortizes_weights() {
        let l = conv_layer(512, 7, 512, 3); // weight-dominated
        let c = cfg(BufferStrategy::FmAccumInBram);
        let b1 = layer_latency(&l, &c, 1.0, 1);
        let b8 = layer_latency(&l, &c, 1.0, 8);
        assert!(b8.total_s < b1.total_s, "b8 {} b1 {}", b8.total_s, b1.total_s);
    }

    #[test]
    fn large_fm_not_resident_small_is() {
        let c = cfg(BufferStrategy::FmAccumInBram);
        let small = conv_layer(64, 28, 64, 3);
        let big = conv_layer(64, 512, 64, 3);
        assert!(layer_latency(&small, &c, 19.2, 1).fm_resident);
        assert!(!layer_latency(&big, &c, 19.2, 1).fm_resident);
    }

    #[test]
    fn strategy2_picks_ws_when_weight_refetch_dominates() {
        // Large output map + big weights: IS must refetch the weights
        // G_fm times (accum buffer too small for the map), so WS's
        // load-weights-once schedule wins under strategy 2.
        let l = conv_layer(512, 56, 512, 3);
        let c = cfg(BufferStrategy::AllInBram);
        let d = layer_latency(&l, &c, 2.0, 1);
        assert!(d.g_fm > 1.0, "test premise: G_fm {} should exceed 1", d.g_fm);
        assert_eq!(d.dataflow, Dataflow::WeightStationary);
    }

    #[test]
    fn strategy2_keeps_is_when_everything_fits() {
        // Small maps and weights: one pass either way; IS is the default.
        let l = conv_layer(64, 14, 64, 3);
        let c = cfg(BufferStrategy::AllInBram);
        let d = layer_latency(&l, &c, 19.2, 1);
        assert_eq!(d.dataflow, Dataflow::InputStationary);
    }

    #[test]
    fn estimate_sums_layers() {
        let l1 = conv_layer(64, 56, 64, 3);
        let l2 = conv_layer(64, 56, 128, 3);
        let c = cfg(BufferStrategy::FmAccumInBram);
        let e = estimate(&[&l1, &l2], &c, 19.2, 1);
        assert_eq!(e.layers.len(), 2);
        assert!(e.period_s >= e.layers[0].total_s.max(e.layers[1].total_s));
        assert!(e.throughput_fps > 0.0 && e.gops > 0.0);
    }

    #[test]
    fn resources_include_weight_bram_only_for_strategy2() {
        let c1 = cfg(BufferStrategy::FmAccumInBram).resources();
        let c2 = cfg(BufferStrategy::AllInBram).resources();
        assert!(c2.bram18k != c1.bram18k);
        assert_eq!(c1.dsp, c2.dsp);
    }

    #[test]
    fn depthwise_effective_parallelism() {
        // Depthwise conv: C/groups = 1 → only 1 lane of CPF is usable.
        let input = TensorShape::new(64, 56, 56);
        let l = Layer {
            name: "dw".into(),
            kind: LayerKind::Conv { kernel: 3, kernel_w: 3, stride: 1, pad: 1, groups: 64 },
            input,
            output: TensorShape::new(64, 56, 56),
            precision: Precision::Int16,
        };
        let c = cfg(BufferStrategy::FmAccumInBram);
        let d = layer_latency(&l, &c, 10_000.0, 1);
        let expect = l.macs() as f64 / (1.0 * 64.0 * 200e6);
        assert!((d.comp_s - expect).abs() / expect < 1e-12);
    }
}
