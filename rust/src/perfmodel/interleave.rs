//! Analytic model of a replicated, frame-interleaved pipeline.
//!
//! A [`crate::shard`] plan is a chain of stages; stage `s` may be
//! replicated across `r_s` boards, with frames issued round-robin to the
//! replicas and re-ordered on the way out. This module is the *single
//! source of truth* for what that buys:
//!
//! * **Throughput** — a replicated stage serves `r_s` frames per stage
//!   interval, so its effective rate is `r_s · f_s`. The cut between
//!   stages `s` and `s+1` runs over `min(r_s, r_{s+1})` parallel links
//!   ([`LinkModel::fan_throughput_fps`]). Steady state is the min over
//!   both families ([`steady_state_fps`]).
//! * **Latency** — a single frame traverses exactly one replica per
//!   stage and one link per cut, so replication leaves the frame latency
//!   untouched: `Σ_s latency_s + Σ_cut hop_s` ([`frame_latency_s`]).
//!   (The reorder buffer adds no steady-state delay for deterministic
//!   service times: frames issued in order to identical replicas
//!   complete in order per replica.)
//!
//! The shard planner's DP computes the same quantities incrementally;
//! `tests/sim_vs_model.rs` cross-validates this closed form against the
//! discrete-event simulator ([`crate::sim::shard`]) and the live
//! [`crate::coordinator::ShardedPipeline`] on every plan shape.

use crate::perfmodel::link::LinkModel;
use crate::topo::{SlotRun, Topology};

/// One stage of a replicated pipeline, as the analytic model sees it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StageRate {
    /// Boards running this stage (round-robin interleaved); >= 1.
    pub replicas: usize,
    /// Per-replica steady-state frame rate.
    pub fps: f64,
    /// Per-replica single-frame latency, seconds.
    pub latency_s: f64,
}

impl StageRate {
    pub fn new(replicas: usize, fps: f64, latency_s: f64) -> Self {
        Self { replicas, fps, latency_s }
    }

    /// Effective stage rate: `replicas × fps` (exactly `fps` at r = 1).
    pub fn effective_fps(&self) -> f64 {
        self.replicas.max(1) as f64 * self.fps
    }
}

/// Steady-state frame rate of the whole chain: the min over effective
/// stage rates and cut ceilings. `cut_bytes[s]` is the tensor crossing
/// the cut between stages `s` and `s+1` (`cut_bytes.len() ==
/// stages.len() - 1`); an empty chain rates 0.
pub fn steady_state_fps(stages: &[StageRate], link: &LinkModel, cut_bytes: &[f64]) -> f64 {
    debug_assert_eq!(cut_bytes.len() + 1, stages.len().max(1));
    let mut fps = f64::INFINITY;
    for (s, stage) in stages.iter().enumerate() {
        fps = fps.min(stage.effective_fps());
        if s + 1 < stages.len() {
            fps = fps.min(link.fan_throughput_fps(
                cut_bytes[s],
                stage.replicas,
                stages[s + 1].replicas,
            ));
        }
    }
    if fps.is_finite() {
        fps
    } else {
        0.0
    }
}

/// Single-frame latency of the chain: per-stage latencies plus the hop
/// cost of each cut, in pipeline order (replication-invariant).
pub fn frame_latency_s(stages: &[StageRate], link: &LinkModel, cut_bytes: &[f64]) -> f64 {
    debug_assert_eq!(cut_bytes.len() + 1, stages.len().max(1));
    let mut latency = 0.0f64;
    for (s, stage) in stages.iter().enumerate() {
        if s > 0 {
            latency += link.transfer_s(cut_bytes[s - 1]);
        }
        latency += stage.latency_s;
    }
    latency
}

/// Topology-aware steady state: the min over effective stage rates and
/// *per-cut* topology-resolved ceilings, then the shared-fabric ceiling
/// (`bisection / Σ cut_bytes` on a switch; a no-op elsewhere).
/// `slots[s]` is where stage `s`'s replica group sits in the cluster.
///
/// On a [`crate::topo::FabricKind::PointToPoint`] topology this is
/// bit-exactly [`steady_state_fps`]: the per-cut resolution degenerates
/// to [`LinkModel::fan_throughput_fps`] and the fabric term to `+∞`
/// (pinned by proptest).
pub fn steady_state_fps_on(
    topo: &Topology,
    stages: &[StageRate],
    slots: &[SlotRun],
    cut_bytes: &[f64],
) -> f64 {
    debug_assert_eq!(cut_bytes.len() + 1, stages.len().max(1));
    debug_assert_eq!(slots.len(), stages.len());
    let mut fps = f64::INFINITY;
    let mut total_bytes = 0.0f64;
    for (s, stage) in stages.iter().enumerate() {
        fps = fps.min(stage.effective_fps());
        if s + 1 < stages.len() {
            fps = fps.min(topo.cut_throughput_fps(cut_bytes[s], slots[s], slots[s + 1]));
            total_bytes += cut_bytes[s];
        }
    }
    fps = fps.min(topo.fabric_fps(total_bytes));
    if fps.is_finite() {
        fps
    } else {
        0.0
    }
}

/// Topology-aware single-frame latency: stage latencies plus each cut's
/// topology-resolved hop cost, in pipeline order. Bit-exactly
/// [`frame_latency_s`] on a point-to-point topology.
pub fn frame_latency_s_on(
    topo: &Topology,
    stages: &[StageRate],
    slots: &[SlotRun],
    cut_bytes: &[f64],
) -> f64 {
    debug_assert_eq!(cut_bytes.len() + 1, stages.len().max(1));
    debug_assert_eq!(slots.len(), stages.len());
    let mut latency = 0.0f64;
    for (s, stage) in stages.iter().enumerate() {
        if s > 0 {
            latency += topo.cut_transfer_s(cut_bytes[s - 1], slots[s - 1], slots[s]);
        }
        latency += stage.latency_s;
    }
    latency
}

/// Stage-order board placement for a chain of replica groups: stage `s`
/// occupies the next `replicas` slots — exactly how the shard planner
/// tiles a cluster (and how hand-built sim specs are interpreted).
pub fn chain_slots(stages: &[StageRate]) -> Vec<SlotRun> {
    let mut slots = Vec::with_capacity(stages.len());
    let mut first = 0usize;
    for s in stages {
        let len = s.replicas.max(1);
        slots.push(SlotRun::new(first, len));
        first += len;
    }
    slots
}

#[cfg(test)]
mod tests {
    use super::*;

    fn link() -> LinkModel {
        LinkModel::new(10.0, 2e-6)
    }

    #[test]
    fn unreplicated_chain_is_the_plain_min() {
        let stages = [
            StageRate::new(1, 100.0, 1e-3),
            StageRate::new(1, 80.0, 2e-3),
            StageRate::new(1, 120.0, 5e-4),
        ];
        let cuts = [1e6, 2e6];
        let fps = steady_state_fps(&stages, &link(), &cuts);
        // Board 1 (80 fps) is slower than both links (1e4 and 5e3 fps).
        assert_eq!(fps, 80.0);
        let lat = frame_latency_s(&stages, &link(), &cuts);
        let expect = 1e-3 + link().transfer_s(1e6) + 2e-3 + link().transfer_s(2e6) + 5e-4;
        assert!((lat - expect).abs() < 1e-12, "{lat} vs {expect}");
    }

    #[test]
    fn replication_multiplies_the_stage_rate_not_the_latency() {
        let solo = [StageRate::new(1, 50.0, 1e-3)];
        let duo = [StageRate::new(2, 50.0, 1e-3)];
        assert_eq!(steady_state_fps(&solo, &link(), &[]), 50.0);
        assert_eq!(steady_state_fps(&duo, &link(), &[]), 100.0);
        assert_eq!(
            frame_latency_s(&solo, &link(), &[]),
            frame_latency_s(&duo, &link(), &[])
        );
    }

    #[test]
    fn cut_ceiling_uses_the_narrow_side() {
        // Fast stages; a 1->2 cut leaves the producer's single egress
        // link as the bottleneck even though the consumers could take 2x.
        let stages = [StageRate::new(1, 1e6, 0.0), StageRate::new(2, 1e6, 0.0)];
        let bytes = 1e6; // 10 GB/s / 1 MB = 1e4 fps per link
        let fps = steady_state_fps(&stages, &link(), &[bytes]);
        assert_eq!(fps, link().throughput_fps(bytes));
        // 2->2 doubles the cut.
        let stages2 = [StageRate::new(2, 1e6, 0.0), StageRate::new(2, 1e6, 0.0)];
        assert_eq!(
            steady_state_fps(&stages2, &link(), &[bytes]),
            2.0 * link().throughput_fps(bytes)
        );
    }

    #[test]
    fn empty_and_zero_cut_edge_cases() {
        assert_eq!(steady_state_fps(&[], &link(), &[]), 0.0);
        assert_eq!(frame_latency_s(&[], &link(), &[]), 0.0);
        // A zero-byte cut never bounds the chain.
        let stages = [StageRate::new(1, 10.0, 0.0), StageRate::new(1, 20.0, 0.0)];
        assert_eq!(steady_state_fps(&stages, &link(), &[0.0]), 10.0);
    }

    #[test]
    fn p2p_topology_closed_form_is_bit_identical() {
        let topo = Topology::point_to_point(link());
        let stages = [
            StageRate::new(1, 100.0, 1e-3),
            StageRate::new(2, 80.0, 2e-3),
            StageRate::new(1, 120.0, 5e-4),
        ];
        let slots = chain_slots(&stages);
        let cuts = [1e6, 2e6];
        assert_eq!(
            steady_state_fps_on(&topo, &stages, &slots, &cuts).to_bits(),
            steady_state_fps(&stages, &link(), &cuts).to_bits()
        );
        assert_eq!(
            frame_latency_s_on(&topo, &stages, &slots, &cuts).to_bits(),
            frame_latency_s(&stages, &link(), &cuts).to_bits()
        );
    }

    #[test]
    fn star_fabric_ceiling_binds_the_chain() {
        // Fast stages and fat cuts through a 1 GB/s switch: the fabric
        // term (1e9 / 2e6 = 500 fps) governs, below every per-cut lane
        // ceiling (10 GB/s / 1 MB = 1e4 fps each).
        let topo = Topology::star(link(), 1.0);
        let stages = [
            StageRate::new(1, 1e6, 0.0),
            StageRate::new(1, 1e6, 0.0),
            StageRate::new(1, 1e6, 0.0),
        ];
        let slots = chain_slots(&stages);
        let cuts = [1e6, 1e6];
        let fps = steady_state_fps_on(&topo, &stages, &slots, &cuts);
        assert!((fps - 500.0).abs() < 1e-9, "{fps}");
        // Removing one cut's traffic relaxes the shared ceiling.
        let relaxed = steady_state_fps_on(&topo, &stages, &slots, &[1e6, 0.0]);
        assert!((relaxed - 1000.0).abs() < 1e-9, "{relaxed}");
    }

    #[test]
    fn ring_cut_stays_single_lane() {
        let topo = Topology::ring(link());
        let stages = [StageRate::new(2, 1e6, 0.0), StageRate::new(2, 1e6, 0.0)];
        let slots = chain_slots(&stages);
        let bytes = 1e6;
        // p2p would give 2 lanes; the ring boundary link gives 1.
        let fps = steady_state_fps_on(&topo, &stages, &slots, &[bytes]);
        assert_eq!(fps, link().throughput_fps(bytes));
        // And the frame pays 3 hops of latency (slot span 0..3).
        let lat = frame_latency_s_on(&topo, &stages, &slots, &[bytes]);
        let expect = topo.cut_transfer_s(bytes, slots[0], slots[1]);
        assert_eq!(lat.to_bits(), expect.to_bits());
    }

    #[test]
    fn chain_slots_tile_in_stage_order() {
        let stages = [
            StageRate::new(1, 1.0, 0.0),
            StageRate::new(3, 1.0, 0.0),
            StageRate::new(2, 1.0, 0.0),
        ];
        let slots = chain_slots(&stages);
        assert_eq!(slots[0], SlotRun::new(0, 1));
        assert_eq!(slots[1], SlotRun::new(1, 3));
        assert_eq!(slots[2], SlotRun::new(4, 2));
    }
}
