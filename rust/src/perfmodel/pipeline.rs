//! Pipeline-structure analytical model (paper §6.1).
//!
//! Each of the first `SP` major layers gets a dedicated stage with a
//! two-dim parallelism `(CPF_i, KPF_i)`. Latency follows Eq. 3:
//!
//! ```text
//! L_i = H_i·W_i·R_i·S_i·C_i·K_i / (CPF_i·KPF_i·FREQ)
//! ```
//!
//! and throughput follows Eq. 4, `Batch / max(L_i)`, where each stage's
//! steady-state initiation interval additionally accounts for streaming
//! the stage's weights from external memory once per batch (the
//! fine-grained pipeline of [DNNBuilder] overlaps weight streaming with
//! compute; the interval is their max).


use crate::dnn::{Layer, Precision};
use crate::fpga::resource::{bram18k_for, ResourceBudget};

/// Per-stage hardware configuration (the paper's four knobs: CPF, KPF,
/// DW, WW).
#[derive(Debug, Clone, Copy)]
pub struct StageConfig {
    pub cpf: usize,
    pub kpf: usize,
    /// Activation (feature map) bit-width.
    pub dw: Precision,
    /// Weight bit-width.
    pub ww: Precision,
}

impl StageConfig {
    pub fn pf(&self) -> u64 {
        (self.cpf * self.kpf) as u64
    }
}

/// Whole pipeline-structure configuration.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub stages: Vec<StageConfig>,
    pub batch: usize,
    pub freq_mhz: f64,
}

/// Per-stage estimate detail.
#[derive(Debug, Clone)]
pub struct StageEstimate {
    /// Compute latency of one frame through this stage (Eq. 3), seconds.
    pub compute_s: f64,
    /// Weight-streaming time for one batch at the stage's share of
    /// pipeline bandwidth, seconds.
    pub weight_stream_s: f64,
    /// Steady-state initiation interval per batch, seconds.
    pub interval_s: f64,
    pub resources: ResourceBudget,
}

/// Pipeline-structure estimate.
#[derive(Debug, Clone)]
pub struct PipelineEstimate {
    pub stages: Vec<StageEstimate>,
    /// Frames per second (already includes batch).
    pub throughput_fps: f64,
    /// Sustained GOP/s over the covered layers.
    pub gops: f64,
    /// Index of the bottleneck stage.
    pub bottleneck: usize,
    pub resources: ResourceBudget,
    /// End-to-end latency of one frame (fill latency), seconds.
    pub frame_latency_s: f64,
}

/// Estimate the pipeline structure over `layers` (the first SP major
/// layers) with per-stage configs and an external bandwidth budget
/// `bw_gbps` shared by all stages' weight streams plus the input stream.
pub fn estimate(
    layers: &[&Layer],
    cfg: &PipelineConfig,
    bw_gbps: f64,
) -> anyhow::Result<PipelineEstimate> {
    anyhow::ensure!(
        layers.len() == cfg.stages.len(),
        "stage count {} != layer count {}",
        cfg.stages.len(),
        layers.len()
    );
    anyhow::ensure!(cfg.batch >= 1, "batch must be >= 1");
    let freq = cfg.freq_mhz * 1e6;
    let batch = cfg.batch as f64;

    // Bandwidth split: the input stream plus each stage's weight stream
    // share bw proportionally to their traffic per batch.
    let input_bytes = layers
        .first()
        .map(|l| l.ifm_bytes(cfg.stages[0].dw) * batch)
        .unwrap_or(0.0);
    let weight_bytes: Vec<f64> = layers
        .iter()
        .zip(&cfg.stages)
        .map(|(l, s)| l.weight_bytes(s.ww))
        .collect();
    let total_traffic = input_bytes + weight_bytes.iter().sum::<f64>();
    let bw_bytes = bw_gbps * 1e9;

    let mut stages = Vec::with_capacity(layers.len());
    let mut total = ResourceBudget::default();
    let mut worst = 0.0f64;
    let mut bottleneck = 0usize;
    let mut fill = 0.0f64;

    for (i, (l, s)) in layers.iter().zip(&cfg.stages).enumerate() {
        // Eq. 3 with integer lane quantization: the PE array retires
        // ceil(C/CPF)·ceil(K/KPF) vector steps per output pixel, so
        // non-dividing CPF/KPF waste lanes (the real hardware behaviour;
        // plain Eq. 3 is the ideal-fractional limit).
        let c_dim = (l.input.c / l.groups()).max(1);
        let steps = (c_dim as f64 / s.cpf as f64).ceil()
            * (l.output.c as f64 / s.kpf as f64).ceil();
        let pixels = (l.output.h * l.output.w) as f64;
        let win = (l.kernel() * l.kernel_w()) as f64;
        let compute_s = pixels * win * steps / freq;
        // Weight streaming once per batch at this stage's bw share.
        let bw_share = if total_traffic > 0.0 {
            bw_bytes * (weight_bytes[i] / total_traffic)
        } else {
            bw_bytes
        };
        let weight_stream_s = if weight_bytes[i] > 0.0 && bw_share > 0.0 {
            weight_bytes[i] / bw_share
        } else {
            0.0
        };
        // Steady state: the stage must finish `batch` frames of compute
        // and one weight refresh per batch period (overlapped → max).
        let interval_s = (compute_s * batch).max(weight_stream_s);
        let resources = stage_resources(l, s);
        total = total.plus(&resources);
        if interval_s > worst {
            worst = interval_s;
            bottleneck = i;
        }
        // Fine-grained (column-based) pipeline: the next stage starts
        // after ~kernel/H of the frame, not the whole frame. Fill adds a
        // fraction of each stage's compute.
        let frac = (l.kernel() as f64 + 1.0) / l.output.h.max(1) as f64;
        fill += compute_s * frac.min(1.0);
        stages.push(StageEstimate {
            compute_s,
            weight_stream_s,
            interval_s,
            resources,
        });
    }
    // Input stream also consumes bandwidth; account it as a floor on the
    // batch period.
    let input_share = if total_traffic > 0.0 {
        bw_bytes * (input_bytes / total_traffic)
    } else {
        bw_bytes
    };
    if input_bytes > 0.0 && input_share > 0.0 {
        let t_in = input_bytes / input_share;
        if t_in > worst {
            worst = t_in;
            // bandwidth-bound on the input stream; attribute to stage 0
            bottleneck = 0;
        }
    }
    total.bw_gbps = if worst > 0.0 {
        total_traffic / worst / 1e9
    } else {
        0.0
    };

    let throughput_fps = if worst > 0.0 { batch / worst } else { 0.0 };
    let ops: f64 = layers.iter().map(|l| l.ops() as f64).sum();
    let frame_latency_s = fill + worst / batch;
    Ok(PipelineEstimate {
        stages,
        throughput_fps,
        gops: throughput_fps * ops / 1e9,
        bottleneck,
        resources: total,
        frame_latency_s,
    })
}

/// Resource usage of one pipeline stage.
///
/// * DSP: `CPF·KPF` MACs at the precision's DSP cost.
/// * BRAM, two terms that drive the paper's depth cliff (Fig. 2b/11):
///   1. **weight-feed banks** — every cycle all `KPF` PEs consume a
///      `CPF·WW`-bit weight word in parallel, so the weight buffer is
///      partitioned into `KPF` banks of `ceil(CPF·WW/36)` BRAM columns
///      each (the banks are shallow — one `R·S` double-buffered tile —
///      so the block count is set by the port width, not the bits).
///      This makes stage BRAM grow ∝ parallelism.
///   2. **column buffer** — the fine-grained pipeline caches `S+1`
///      input columns; the read window is double-buffered against the
///      producer stage while trailing columns are single-copy, giving
///      `1.5·(S+1)·H_in·C_in·DW` bits. This is a *fixed* cost per
///      instantiated stage, so it grows with network depth — deep
///      pipelines exhaust BRAM and must shrink PF, which is exactly the
///      scalability flaw the paper identifies (Fig. 2b).
pub fn stage_resources(l: &Layer, s: &StageConfig) -> ResourceBudget {
    let dsp = (s.pf() as f64) * s.ww.dsp_per_mac();
    let bank_cols = ((s.cpf as f64) * s.ww.bits() as f64 / 36.0).ceil().max(1.0);
    let weight_banks = (s.kpf as f64) * bank_cols;
    // 1.5×: the read window (S+1 columns) is double-buffered against the
    // writer, but the trailing columns are single-copy.
    let col_bits =
        1.5 * ((l.kernel_w() + 1) * l.input.h * l.input.c) as f64 * s.dw.bits() as f64;
    let cport = (s.cpf as f64 * s.dw.bits() as f64).max(s.dw.bits() as f64);
    let bram = weight_banks + bram18k_for(col_bits, cport);
    ResourceBudget::new(dsp, bram, 0.0)
}

/// Round a parallelism target to hardware (CPF, KPF) factors.
///
/// Candidates are powers of two plus the exact channel counts (DNNBuilder
/// instantiates CPF = 3 for the RGB input layer rather than wasting a
/// fourth lane). Among configs within the `pf_target` lane budget, pick
/// the one minimizing the real step count `ceil(C/CPF)·ceil(K/KPF)` —
/// i.e. the fastest configuration the budget can buy; ties go to fewer
/// lanes.
pub fn factorize_pf(pf_target: f64, c: usize, k: usize) -> (usize, usize) {
    Factorizer::new(c, k).pick(pf_target)
}

/// Reusable per-layer factorizer: the candidate lane ladders (2^i and
/// 3·2^i — the unroll factors HLS designs actually instantiate; 1.5×
/// steps avoid the power-of-two throughput cliff — plus the exact
/// channel count) are computed once and reused across the optimizer's
/// many shrink/grow probes (§Perf attempt 6).
pub struct Factorizer {
    c: usize,
    k: usize,
    c_cands: Vec<usize>,
    k_cands: Vec<usize>,
}

impl Factorizer {
    pub fn new(c: usize, k: usize) -> Self {
        let cands = |dim: usize, cap: usize| -> Vec<usize> {
            let lim = dim.next_power_of_two().min(cap);
            let mut v: Vec<usize> = Vec::new();
            let mut p = 1usize;
            while p <= lim {
                v.push(p);
                if p >= 2 && 3 * p / 2 <= lim {
                    v.push(3 * p / 2);
                }
                p *= 2;
            }
            if dim <= cap && !v.contains(&dim) {
                v.push(dim);
            }
            v
        };
        Self { c, k, c_cands: cands(c, 64), k_cands: cands(k, 512) }
    }

    /// Best (CPF, KPF) within the lane budget: minimize the real step
    /// count `ceil(C/CPF)·ceil(K/KPF)`, ties to fewer lanes.
    pub fn pick(&self, pf_target: f64) -> (usize, usize) {
        let budget = pf_target.max(1.0);
        let steps = |cpf: usize, kpf: usize| -> f64 {
            (self.c as f64 / cpf as f64).ceil() * (self.k as f64 / kpf as f64).ceil()
        };
        let mut best = (1usize, 1usize);
        let mut best_key = (steps(1, 1), 1usize);
        for &cpf in &self.c_cands {
            for &kpf in &self.k_cands {
                if (cpf * kpf) as f64 > budget + 1e-9 {
                    continue;
                }
                let key = (steps(cpf, kpf), cpf * kpf);
                if key.0 < best_key.0 || (key.0 == best_key.0 && key.1 < best_key.1) {
                    best_key = key;
                    best = (cpf, kpf);
                }
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::zoo;
    use crate::dnn::TensorShape;

    fn vgg_layers(h: usize, w: usize) -> Vec<crate::dnn::Layer> {
        zoo::vgg16_conv(TensorShape::new(3, h, w), Precision::Int16)
            .layers
            .into_iter()
            .filter(|l| l.is_compute())
            .collect()
    }

    fn uniform_cfg(n: usize, cpf: usize, kpf: usize, batch: usize) -> PipelineConfig {
        PipelineConfig {
            stages: vec![
                StageConfig {
                    cpf,
                    kpf,
                    dw: Precision::Int16,
                    ww: Precision::Int16,
                };
                n
            ],
            batch,
            freq_mhz: 200.0,
        }
    }

    #[test]
    fn eq3_latency_exact() {
        let layers = vgg_layers(224, 224);
        let refs: Vec<&crate::dnn::Layer> = layers.iter().take(1).collect();
        let cfg = uniform_cfg(1, 3, 16, 1);
        let est = estimate(&refs, &cfg, 1000.0).unwrap(); // ample bw
        let expect = layers[0].macs() as f64 / (48.0 * 200e6);
        assert!((est.stages[0].compute_s - expect).abs() / expect < 1e-9);
    }

    #[test]
    fn throughput_limited_by_worst_stage() {
        let layers = vgg_layers(224, 224);
        let refs: Vec<&crate::dnn::Layer> = layers.iter().collect();
        let cfg = uniform_cfg(refs.len(), 16, 16, 1);
        let est = estimate(&refs, &cfg, 19.2).unwrap();
        let worst = est
            .stages
            .iter()
            .map(|s| s.interval_s)
            .fold(0.0f64, f64::max);
        assert!((est.throughput_fps - 1.0 / worst).abs() / est.throughput_fps < 1e-9);
    }

    #[test]
    fn batch_amortizes_weight_streaming() {
        // A weight-heavy layer: batch should raise fps when weight
        // streaming dominates.
        let layers = vgg_layers(32, 32);
        let refs: Vec<&crate::dnn::Layer> = layers.iter().collect();
        let b1 = estimate(&refs, &uniform_cfg(refs.len(), 32, 32, 1), 6.0).unwrap();
        let b8 = estimate(&refs, &uniform_cfg(refs.len(), 32, 32, 8), 6.0).unwrap();
        assert!(
            b8.throughput_fps > b1.throughput_fps * 1.5,
            "b1 {} b8 {}",
            b1.throughput_fps,
            b8.throughput_fps
        );
    }

    #[test]
    fn resources_scale_with_pf() {
        let layers = vgg_layers(224, 224);
        let refs: Vec<&crate::dnn::Layer> = layers.iter().take(3).collect();
        let small = estimate(&refs, &uniform_cfg(3, 4, 4, 1), 19.2).unwrap();
        let big = estimate(&refs, &uniform_cfg(3, 16, 16, 1), 19.2).unwrap();
        assert!(big.resources.dsp > small.resources.dsp * 10.0);
    }

    #[test]
    fn factorize_pf_respects_budget_and_minimizes_steps() {
        let (c, k) = factorize_pf(100.0, 64, 512);
        assert!(c * k <= 100, "budget exceeded: {c}x{k}");
        let (c, k) = factorize_pf(0.5, 3, 64);
        assert_eq!((c, k), (1, 1));
        // Exact channel counts beat wasteful powers of two: with C = 3
        // a CPF of 3 gives the same steps as 4 with fewer lanes.
        let (c, _k) = factorize_pf(3.0 * 64.0, 3, 64);
        assert_eq!(c, 3, "should use the exact RGB depth");
        // Never exceed the useful dimensions.
        let (c, k) = factorize_pf(1e9, 64, 512);
        assert!(c <= 64 && k <= 512);
    }

    #[test]
    fn stage_count_mismatch_errors() {
        let layers = vgg_layers(224, 224);
        let refs: Vec<&crate::dnn::Layer> = layers.iter().take(2).collect();
        assert!(estimate(&refs, &uniform_cfg(3, 4, 4, 1), 19.2).is_err());
    }
}
