//! Inter-board link model: the transfer cost a sharded pipeline pays for
//! the activation tensor crossing each cut (see [`crate::shard`]).
//!
//! The model is the standard latency/bandwidth line: moving `B` bytes
//! over a link costs `latency_s + B / bandwidth`. For a *pipelined*
//! stream of frames the fixed latency overlaps with compute, so the
//! link's throughput ceiling is set by the serialization term alone
//! (`bandwidth / B` frames per second), while the end-to-end latency of
//! a single frame pays the full hop cost. Both views are exposed and the
//! shard planner charges each where it belongs: serialization bounds the
//! pipeline's steady-state rate, the hop cost adds to frame latency.

/// A point-to-point inter-board link (direction-less; each cut in a
/// shard plan crosses one such link).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkModel {
    /// Sustained payload bandwidth in GB/s.
    pub bandwidth_gbps: f64,
    /// Fixed per-transfer latency in seconds (serdes + protocol + switch).
    pub latency_s: f64,
}

impl LinkModel {
    pub fn new(bandwidth_gbps: f64, latency_s: f64) -> Self {
        Self { bandwidth_gbps, latency_s }
    }

    /// Bandwidth in bytes/second.
    pub fn bandwidth_bytes(&self) -> f64 {
        self.bandwidth_gbps * 1e9
    }

    /// Time to move one `bytes`-sized tensor across the link (one hop):
    /// fixed latency plus serialization.
    pub fn transfer_s(&self, bytes: f64) -> f64 {
        if bytes <= 0.0 {
            return 0.0;
        }
        self.latency_s + bytes / self.bandwidth_bytes().max(1.0)
    }

    /// Steady-state frame rate the link sustains for `bytes` per frame
    /// (pipelined transfers: only serialization limits the rate).
    /// Infinite when the cut carries no data.
    pub fn throughput_fps(&self, bytes: f64) -> f64 {
        if bytes <= 0.0 {
            return f64::INFINITY;
        }
        self.bandwidth_bytes().max(1.0) / bytes
    }

    /// Steady-state ceiling of a cut between a stage replicated on
    /// `r_from` boards and one replicated on `r_to` boards, with frames
    /// interleaved round-robin on both sides.
    ///
    /// Each board has one link of this model. A frame crosses the cut
    /// exactly once, occupying one producer egress link and one consumer
    /// ingress link for its serialization time. Round-robin spreads the
    /// stream evenly, so the busiest side is the one with fewer boards:
    /// with `r_from < r_to` every producer link still carries
    /// `1/r_from` of all frames (and symmetrically for fan-in), giving a
    /// cut ceiling of `min(r_from, r_to)` parallel serializations.
    ///
    /// `r = 1` on both sides reduces bit-exactly to
    /// [`Self::throughput_fps`] (the multiplier is `1.0`).
    pub fn fan_throughput_fps(&self, bytes: f64, r_from: usize, r_to: usize) -> f64 {
        let lanes = r_from.min(r_to).max(1) as f64;
        lanes * self.throughput_fps(bytes)
    }
}

impl Default for LinkModel {
    /// A 100 GbE-class board-to-board link: ~12 GB/s sustained payload,
    /// 2 µs fixed hop latency — the common deployment for FPGA
    /// SmartNIC/accelerator clusters.
    fn default() -> Self {
        Self { bandwidth_gbps: 12.0, latency_s: 2e-6 }
    }
}

impl std::fmt::Display for LinkModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:.1} GB/s + {:.1}us/hop",
            self.bandwidth_gbps,
            self.latency_s * 1e6
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_cost_is_latency_plus_serialization() {
        let l = LinkModel::new(10.0, 5e-6);
        let t = l.transfer_s(1e6); // 1 MB at 10 GB/s = 100us + 5us
        assert!((t - 105e-6).abs() < 1e-9, "{t}");
        assert_eq!(l.transfer_s(0.0), 0.0);
    }

    #[test]
    fn pipelined_rate_ignores_fixed_latency() {
        let fast = LinkModel::new(10.0, 1e-3); // terrible latency
        let slow = LinkModel::new(10.0, 1e-9);
        assert_eq!(fast.throughput_fps(1e6), slow.throughput_fps(1e6));
        assert!((fast.throughput_fps(1e6) - 1e4).abs() < 1e-6);
        assert!(fast.throughput_fps(0.0).is_infinite());
    }

    #[test]
    fn fan_throughput_scales_with_the_narrow_side() {
        let l = LinkModel::new(10.0, 1e-6);
        let base = l.throughput_fps(1e6);
        // 1->1 is bit-exactly the plain serialization rate.
        assert_eq!(l.fan_throughput_fps(1e6, 1, 1).to_bits(), base.to_bits());
        // The narrow side bounds the cut: one producer can only fill one
        // egress link no matter how many consumers wait.
        assert_eq!(l.fan_throughput_fps(1e6, 1, 4), base);
        assert_eq!(l.fan_throughput_fps(1e6, 4, 1), base);
        assert_eq!(l.fan_throughput_fps(1e6, 2, 3), 2.0 * base);
        assert_eq!(l.fan_throughput_fps(1e6, 3, 3), 3.0 * base);
        // Empty cuts stay unbounded at any fan shape.
        assert!(l.fan_throughput_fps(0.0, 2, 2).is_infinite());
    }

    #[test]
    fn faster_link_moves_data_faster() {
        let a = LinkModel::new(5.0, 1e-6);
        let b = LinkModel::new(50.0, 1e-6);
        assert!(b.transfer_s(1e7) < a.transfer_s(1e7));
        assert!(b.throughput_fps(1e7) > a.throughput_fps(1e7));
    }
}
