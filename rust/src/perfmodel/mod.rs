//! Analytical performance & resource models (paper §6, *Accelerator
//! Modeling*).
//!
//! Two families, matching the two halves of the proposed paradigm:
//!
//! * [`pipeline`] — the layer-dedicated pipeline structure (paper Eq. 3–4
//!   plus a resource model for DSP / BRAM / bandwidth usage).
//! * [`generic`] — the reusable MAC-array structure (paper Eq. 5–13, both
//!   on-chip buffer allocation strategies and both IS/WS dataflows).
//! * [`link`] — the inter-board link model extending the paradigm across
//!   devices: a latency/bandwidth line charging the activation tensor
//!   that crosses each cut of a [`crate::shard`] plan.
//! * [`interleave`] — the closed form for a *replicated* pipeline:
//!   effective stage rates (`r × fps`), fan-out/fan-in cut ceilings
//!   (`min(r_from, r_to)` parallel links), and replication-invariant
//!   frame latency — cross-validated against [`crate::sim::shard`] and
//!   the live pipeline by `tests/sim_vs_model.rs`.
//!
//! All produce latency/throughput estimates in **seconds / frames-per-
//! second / GOP/s**; the structures report resource usage as a
//! [`crate::fpga::ResourceBudget`].

pub mod generic;
pub mod interleave;
pub mod link;
pub mod pipeline;

use crate::dnn::Precision;

/// DSP efficiency per the paper's Eq. 1:
/// `EFFI_DSP = GOPs / (α · DSP_allocated · FREQ)`.
///
/// `gops` in GOP/s, `freq_mhz` in MHz, `dsp` as allocated DSP count.
pub fn dsp_efficiency(gops: f64, precision: Precision, dsp_allocated: f64, freq_mhz: f64) -> f64 {
    if dsp_allocated <= 0.0 || freq_mhz <= 0.0 {
        return 0.0;
    }
    gops / (precision.alpha() * dsp_allocated * freq_mhz / 1e3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq1_matches_paper_table3_row4() {
        // Table 3 case 4: 1702.3 GOP/s, 4444 DSP, 16-bit, 200 MHz -> 95.8%.
        let e = dsp_efficiency(1702.3, Precision::Int16, 4444.0, 200.0);
        assert!((e - 0.958).abs() < 0.005, "eff {e}");
    }

    #[test]
    fn eq1_degenerate_inputs() {
        assert_eq!(dsp_efficiency(100.0, Precision::Int16, 0.0, 200.0), 0.0);
        assert_eq!(dsp_efficiency(100.0, Precision::Int16, 100.0, 0.0), 0.0);
    }
}
