//! Board-interconnect topology: how a cluster's boards are actually
//! wired, and what each shard cut pays for it.
//!
//! The shard planner (PRs 3–4) charged every cut against one uniform
//! point-to-point [`LinkModel`] — correct for dedicated cables, but
//! over-promising on switch-attached or ring-connected clusters where
//! cuts share fabric. This module makes the interconnect a first-class
//! input: a [`Topology`] resolves each cut — given *where* the two
//! replica groups sit in the cluster ([`SlotRun`]s; stage order maps to
//! board slots) — to a per-cut effective link, and a shared-fabric
//! contention model charges the *sum* of concurrent cut traffic
//! crossing a switch against its aggregate bisection bandwidth.
//!
//! Fabrics ([`FabricKind`]):
//!
//! * **`PointToPoint`** — a dedicated cable per cut (the PR 3–4 model).
//!   Every resolution reduces *bit-exactly* to the uniform
//!   [`LinkModel`] path: same calls, same arithmetic (pinned by
//!   proptest).
//! * **`Ring`** — boards chained in slot order, frames forwarded around
//!   the (unidirectional) ring. All of a cut's traffic crosses the
//!   single boundary link between the groups, so the cut ceiling stays
//!   **one lane** no matter how wide the replica fan; hop latency
//!   scales with the worst-case slot distance between paired replicas.
//! * **`Star`** — every board has one full-duplex uplink into a switch
//!   with finite bisection bandwidth. Per-cut ceilings keep the
//!   `min(r_from, r_to)` uplink lanes, a frame pays two serdes
//!   traversals plus store-and-forward through the switch, and — the
//!   contention model — steady-state throughput is additionally capped
//!   by `bisection / Σ cut_bytes` across *all* concurrent cuts
//!   ([`Topology::fabric_fps`]).
//! * **`FullMesh`** — a dedicated link between every board pair;
//!   resolves identically to `PointToPoint` for the chain-shaped
//!   traffic a shard plan generates (pinned bit-exact by proptest).
//!
//! Consumers: `shard::partition` prices every DP transition through
//! [`Topology::cut_throughput_fps`] / [`Topology::cut_transfer_s`] and
//! tracks accumulated cut bytes for the fabric ceiling,
//! [`crate::perfmodel::interleave`] exposes the topology-aware closed
//! forms, [`crate::sim::shard`] simulates joint fabric occupancy, and
//! the CLI grows `shard --topology ring|star:<gbps>|mesh|p2p`.

use crate::perfmodel::link::LinkModel;

/// A contiguous run of cluster board slots — where one replica group
/// sits. Stage order maps to ascending slot order (stage 0 occupies the
/// lowest slots), which is exactly how the shard planner tiles boards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SlotRun {
    /// First board slot of the run.
    pub first: usize,
    /// Number of boards in the run (the replication factor; >= 1).
    pub len: usize,
}

impl SlotRun {
    pub fn new(first: usize, len: usize) -> Self {
        Self { first, len: len.max(1) }
    }

    /// Last board slot of the run.
    pub fn last(&self) -> usize {
        self.first + self.len - 1
    }
}

/// How the cluster's boards are wired together.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum FabricKind {
    /// A dedicated cable per cut (the uniform-link model).
    #[default]
    PointToPoint,
    /// Unidirectional ring in slot order: one boundary link per cut,
    /// hop latency grows with slot distance.
    Ring,
    /// Per-board uplinks into a switch with this much aggregate
    /// bisection bandwidth (GB/s) shared by all concurrent cut traffic.
    Star {
        bisection_gbps: f64,
    },
    /// A dedicated link between every board pair.
    FullMesh,
}

impl FabricKind {
    /// Parse a CLI spec: `p2p`, `ring`, `mesh`, or `star:<gbps>`.
    pub fn parse(spec: &str) -> anyhow::Result<Self> {
        match spec {
            "p2p" => Ok(Self::PointToPoint),
            "ring" => Ok(Self::Ring),
            "mesh" => Ok(Self::FullMesh),
            other => match other.strip_prefix("star:") {
                Some(gbps) => {
                    let b: f64 = gbps
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad star bisection {gbps:?} (GB/s)"))?;
                    anyhow::ensure!(b > 0.0, "star bisection bandwidth must be positive");
                    Ok(Self::Star { bisection_gbps: b })
                }
                None => anyhow::bail!("unknown topology {spec:?} (p2p|ring|star:<gbps>|mesh)"),
            },
        }
    }
}

impl std::fmt::Display for FabricKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::PointToPoint => write!(f, "p2p"),
            Self::Ring => write!(f, "ring"),
            Self::Star { bisection_gbps } => write!(f, "star:{bisection_gbps}"),
            Self::FullMesh => write!(f, "mesh"),
        }
    }
}

/// A board-interconnect graph: one per-port/per-hop [`LinkModel`] plus
/// the wiring pattern. All cut resolution goes through this type.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Topology {
    /// The per-port (p2p/mesh: per-cable; ring: per-segment; star:
    /// per-uplink) link model.
    pub link: LinkModel,
    pub kind: FabricKind,
}

impl Topology {
    pub fn new(link: LinkModel, kind: FabricKind) -> Self {
        Self { link, kind }
    }

    /// Dedicated cable per cut — the uniform-link model.
    pub fn point_to_point(link: LinkModel) -> Self {
        Self::new(link, FabricKind::PointToPoint)
    }

    /// Unidirectional ring in board-slot order.
    pub fn ring(link: LinkModel) -> Self {
        Self::new(link, FabricKind::Ring)
    }

    /// Switch fabric: per-board uplinks of `link`'s shape sharing
    /// `bisection_gbps` GB/s of aggregate switching bandwidth.
    pub fn star(link: LinkModel, bisection_gbps: f64) -> Self {
        Self::new(link, FabricKind::Star { bisection_gbps })
    }

    /// Dedicated link between every board pair.
    pub fn full_mesh(link: LinkModel) -> Self {
        Self::new(link, FabricKind::FullMesh)
    }

    /// Worst-case forward hop count between any producer replica in
    /// `from` and any consumer replica in `to` on the ring: the span
    /// from the earliest producer slot to the latest consumer slot.
    /// Adjacent unreplicated stages give exactly 1 hop.
    fn ring_hops(&self, from: SlotRun, to: SlotRun) -> usize {
        to.last().saturating_sub(from.first).max(1)
    }

    /// Parallel serialization lanes the cut between groups `from` and
    /// `to` runs over: `min(r_from, r_to)` per-board links on
    /// p2p/mesh/star, a single boundary link on the ring.
    pub fn cut_lanes(&self, from: SlotRun, to: SlotRun) -> usize {
        match self.kind {
            FabricKind::Ring => 1,
            _ => from.len.min(to.len).max(1),
        }
    }

    /// Steady-state frame-rate ceiling of one cut: lanes × per-lane
    /// serialization rate. Bit-exactly [`LinkModel::fan_throughput_fps`]
    /// on `PointToPoint`/`FullMesh`.
    pub fn cut_throughput_fps(&self, bytes: f64, from: SlotRun, to: SlotRun) -> f64 {
        match self.kind {
            FabricKind::PointToPoint | FabricKind::FullMesh | FabricKind::Star { .. } => {
                self.link.fan_throughput_fps(bytes, from.len, to.len)
            }
            FabricKind::Ring => self.link.throughput_fps(bytes),
        }
    }

    /// Single-frame cost of crossing one cut (adds to frame latency):
    /// hop latency plus serialization, per fabric. Bit-exactly
    /// [`LinkModel::transfer_s`] on `PointToPoint`/`FullMesh`; the ring
    /// pays one hop latency per slot crossed; the star pays two serdes
    /// traversals plus store-and-forward through the switch.
    pub fn cut_transfer_s(&self, bytes: f64, from: SlotRun, to: SlotRun) -> f64 {
        if bytes <= 0.0 {
            return 0.0;
        }
        let ser = bytes / self.link.bandwidth_bytes().max(1.0);
        match self.kind {
            FabricKind::PointToPoint | FabricKind::FullMesh => self.link.transfer_s(bytes),
            FabricKind::Ring => self.ring_hops(from, to) as f64 * self.link.latency_s + ser,
            FabricKind::Star { bisection_gbps } => {
                2.0 * self.link.latency_s + ser + bytes / (bisection_gbps * 1e9).max(1.0)
            }
        }
    }

    /// Single-frame latency of crossing one cut as the ring simulator
    /// charges it *after* serialization (the pure-delay part of
    /// [`Self::cut_transfer_s`]).
    pub fn cut_hop_s(&self, from: SlotRun, to: SlotRun) -> f64 {
        match self.kind {
            FabricKind::PointToPoint | FabricKind::FullMesh => self.link.latency_s,
            FabricKind::Ring => self.ring_hops(from, to) as f64 * self.link.latency_s,
            FabricKind::Star { .. } => 2.0 * self.link.latency_s,
        }
    }

    /// Aggregate switching bandwidth shared by all concurrent cut
    /// traffic, bytes/second — `Some` only on a switch fabric.
    pub fn fabric_bytes_per_s(&self) -> Option<f64> {
        match self.kind {
            FabricKind::Star { bisection_gbps } => Some((bisection_gbps * 1e9).max(1.0)),
            _ => None,
        }
    }

    /// Whether a shared-fabric ceiling applies (switch fabrics only).
    pub fn has_fabric(&self) -> bool {
        self.fabric_bytes_per_s().is_some()
    }

    /// Steady-state ceiling the shared fabric imposes when every cut of
    /// a plan carries the same frame rate and `total_cut_bytes` is the
    /// sum of bytes crossing the switch per frame: `bisection / Σ`.
    /// Unbounded on fabrics without shared switching (and for plans
    /// with no cut traffic) — `min`-ing it in is then a no-op.
    pub fn fabric_fps(&self, total_cut_bytes: f64) -> f64 {
        match self.fabric_bytes_per_s() {
            Some(b) if total_cut_bytes > 0.0 => b / total_cut_bytes,
            _ => f64::INFINITY,
        }
    }
}

impl Default for Topology {
    fn default() -> Self {
        Self::point_to_point(LinkModel::default())
    }
}

impl std::fmt::Display for Topology {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} over {}", self.kind, self.link)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn link() -> LinkModel {
        LinkModel::new(10.0, 2e-6)
    }

    fn run(first: usize, len: usize) -> SlotRun {
        SlotRun::new(first, len)
    }

    #[test]
    fn parse_round_trips_the_catalogue() {
        assert_eq!(FabricKind::parse("p2p").unwrap(), FabricKind::PointToPoint);
        assert_eq!(FabricKind::parse("ring").unwrap(), FabricKind::Ring);
        assert_eq!(FabricKind::parse("mesh").unwrap(), FabricKind::FullMesh);
        assert_eq!(
            FabricKind::parse("star:8").unwrap(),
            FabricKind::Star { bisection_gbps: 8.0 }
        );
        assert!(FabricKind::parse("star:-1").is_err());
        assert!(FabricKind::parse("star:x").is_err());
        assert!(FabricKind::parse("torus").is_err());
        for s in ["p2p", "ring", "mesh", "star:8"] {
            assert_eq!(format!("{}", FabricKind::parse(s).unwrap()), s);
        }
    }

    #[test]
    fn p2p_and_mesh_reduce_to_the_uniform_link_bitwise() {
        let l = link();
        for topo in [Topology::point_to_point(l), Topology::full_mesh(l)] {
            for (rf, rt) in [(1, 1), (1, 3), (2, 2), (4, 2)] {
                let f = run(0, rf);
                let t = run(rf, rt);
                assert_eq!(
                    topo.cut_throughput_fps(1e6, f, t).to_bits(),
                    l.fan_throughput_fps(1e6, rf, rt).to_bits()
                );
                assert_eq!(
                    topo.cut_transfer_s(1e6, f, t).to_bits(),
                    l.transfer_s(1e6).to_bits()
                );
                assert_eq!(topo.cut_lanes(f, t), rf.min(rt));
            }
            assert_eq!(topo.fabric_fps(1e9), f64::INFINITY);
            assert!(!topo.has_fabric());
        }
    }

    #[test]
    fn ring_keeps_one_lane_and_scales_hops_with_span() {
        let topo = Topology::ring(link());
        // Unreplicated adjacent stages: identical to p2p.
        let p2p = Topology::point_to_point(link());
        let a = run(0, 1);
        let b = run(1, 1);
        assert_eq!(
            topo.cut_throughput_fps(1e6, a, b).to_bits(),
            p2p.cut_throughput_fps(1e6, a, b).to_bits()
        );
        assert_eq!(
            topo.cut_transfer_s(1e6, a, b).to_bits(),
            p2p.cut_transfer_s(1e6, a, b).to_bits()
        );
        // A 2->2 fan: p2p gets 2 lanes, the ring still 1 — all traffic
        // crosses the single boundary segment.
        let f = run(0, 2);
        let t = run(2, 2);
        assert_eq!(topo.cut_lanes(f, t), 1);
        assert_eq!(
            topo.cut_throughput_fps(1e6, f, t),
            0.5 * p2p.cut_throughput_fps(1e6, f, t)
        );
        // Worst-case span 0..3 = 3 hops of latency.
        let hop3 = topo.cut_transfer_s(1e6, f, t) - 1e6 / link().bandwidth_bytes();
        assert!((hop3 - 3.0 * link().latency_s).abs() < 1e-15, "{hop3}");
    }

    #[test]
    fn star_caps_the_sum_of_cut_traffic() {
        let topo = Topology::star(link(), 2.0); // 2 GB/s switch
        assert!(topo.has_fabric());
        // Per-cut lanes behave like per-board uplinks.
        assert_eq!(topo.cut_lanes(run(0, 2), run(2, 3)), 2);
        // The fabric ceiling divides bisection by total bytes...
        assert!((topo.fabric_fps(2e6) - 1000.0).abs() < 1e-9);
        // ...is monotone in traffic...
        assert!(topo.fabric_fps(4e6) < topo.fabric_fps(2e6));
        // ...and never binds with no cut traffic.
        assert_eq!(topo.fabric_fps(0.0), f64::INFINITY);
        // Transfer pays two serdes hops plus switch store-and-forward.
        let t = topo.cut_transfer_s(1e6, run(0, 1), run(1, 1));
        let expect = 2.0 * link().latency_s + 1e6 / link().bandwidth_bytes() + 1e6 / 2e9;
        assert!((t - expect).abs() < 1e-15, "{t} vs {expect}");
    }

    #[test]
    fn zero_byte_cuts_cost_nothing_everywhere() {
        for topo in [
            Topology::point_to_point(link()),
            Topology::ring(link()),
            Topology::star(link(), 1.0),
            Topology::full_mesh(link()),
        ] {
            assert_eq!(topo.cut_transfer_s(0.0, run(0, 1), run(1, 1)), 0.0);
            assert!(topo.cut_throughput_fps(0.0, run(0, 1), run(1, 1)).is_infinite());
        }
    }
}
