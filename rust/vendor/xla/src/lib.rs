//! Compile-only stub of the `xla` PJRT binding.
//!
//! The offline build environment ships no XLA shared libraries, so the
//! real binding cannot link. This stub mirrors the exact API surface
//! `src/runtime/executable.rs` uses; [`PjRtClient::cpu`] fails with a
//! recognizable error, and every consumer (CLI `serve`, the runtime and
//! serving integration tests, the serving benches) already treats an
//! engine-construction failure as "skip the PJRT path". Code paths past
//! client construction are therefore unreachable but still type-checked.

use std::fmt;

/// Stub error: carries a message; printed with `{:?}` by callers.
pub struct Error(String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error("PJRT unavailable: offline xla stub (no XLA shared library in this environment)".into())
}

/// Element types a [`Literal`] can be read back as.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

/// Stub PJRT client; [`PjRtClient::cpu`] always fails.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(unavailable())
    }
}

/// XLA computation handle (stub).
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self(())
    }
}

/// Compiled executable (stub; unreachable at runtime).
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// Device buffer (stub).
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Host literal (stub).
pub struct Literal(());

impl Literal {
    pub fn vec1(_data: &[f32]) -> Self {
        Self(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn shape(&self) -> Result<Shape> {
        Err(unavailable())
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Err(unavailable())
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}

/// Shape of a literal: a tuple or an array.
pub enum Shape {
    Tuple(Vec<Shape>),
    Array(ArrayShape),
}

/// Array shape: dimension sizes.
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_loudly() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        let msg = format!("{err:?}");
        assert!(msg.contains("offline xla stub"), "{msg}");
    }

    #[test]
    fn hlo_parse_fails() {
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
    }
}
