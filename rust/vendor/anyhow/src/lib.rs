//! Minimal offline shim of the `anyhow` API surface this crate uses.
//!
//! The build environment has no crates.io access, so the real `anyhow`
//! cannot be fetched. This shim provides the subset the codebase relies
//! on — [`Error`], [`Result`], the [`anyhow!`]/[`bail!`]/[`ensure!`]
//! macros, and a blanket `From<E: std::error::Error>` conversion so `?`
//! works on io/parse errors — with the same call syntax, so swapping the
//! real crate back in (when a registry is available) is a one-line
//! Cargo.toml change.

use std::fmt;

/// A type-erased error: a display message plus an optional source chain
/// (flattened into the message at conversion time, which is all the
/// consumers here need — `{e}` and `{e:#}` both print the full story).
pub struct Error {
    msg: String,
}

impl Error {
    /// Create an error from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Self { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`;
// that is what makes the blanket conversion below coherent (the same
// trick the real anyhow uses).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Self { msg }
    }
}

/// `anyhow::Result<T>`: a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a message, a format string, or any
/// displayable expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(&$err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!(
                ::std::concat!("condition failed: ", ::std::stringify!($cond))
            ));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        Ok(std::fs::read_to_string("/definitely/not/a/real/path")?)
    }

    fn parse_fail() -> Result<usize> {
        Ok("not-a-number".parse::<usize>()?)
    }

    fn guard(x: i32) -> Result<i32> {
        ensure!(x > 0, "x must be positive, got {x}");
        if x > 100 {
            bail!("x too large: {x}");
        }
        Ok(x)
    }

    #[test]
    fn conversions_via_question_mark() {
        assert!(io_fail().is_err());
        assert!(parse_fail().is_err());
    }

    #[test]
    fn macros_build_messages() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let v = 3;
        let e = anyhow!("value {v} and {}", 4);
        assert_eq!(e.to_string(), "value 3 and 4");
        let owned = String::from("owned message");
        let e = anyhow!(owned.clone());
        assert_eq!(e.to_string(), "owned message");
    }

    #[test]
    fn ensure_and_bail() {
        assert_eq!(guard(5).unwrap(), 5);
        assert!(guard(-1).is_err());
        assert!(guard(101).unwrap_err().to_string().contains("too large"));
    }
}
