"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes/strides/paddings/dtypes; assert_allclose against
``ref``. This is the core correctness signal of the compile path.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import conv_stage, mac_array, ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def rand(shape, seed, dtype=np.float32):
    return jnp.array(np.random.default_rng(seed).standard_normal(shape, dtype=np.float32).astype(dtype))


# ---------------------------------------------------------------- GEMM


@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**16),
)
def test_gemm_matches_oracle(m, k, n, seed):
    a = rand((m, k), seed)
    b = rand((k, n), seed + 1)
    got = mac_array.gemm(a, b, bm=16, bk=16, bn=16)
    assert_allclose(np.array(got), np.array(ref.matmul(a, b)), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("block", [(8, 8, 8), (16, 32, 8), (128, 128, 128)])
def test_gemm_block_shapes(block):
    bm, bk, bn = block
    a = rand((50, 33), 3)
    b = rand((33, 20), 4)
    got = mac_array.gemm(a, b, bm=bm, bk=bk, bn=bn)
    assert_allclose(np.array(got), np.array(ref.matmul(a, b)), rtol=1e-4, atol=1e-4)


def test_gemm_bf16_inputs_accumulate_f32():
    a = rand((32, 32), 5, dtype=jnp.bfloat16)
    b = rand((32, 32), 6, dtype=jnp.bfloat16)
    got = mac_array.gemm(a, b, bm=16, bk=16, bn=16)
    assert got.dtype == jnp.float32
    want = np.array(a, dtype=np.float32) @ np.array(b, dtype=np.float32)
    assert_allclose(np.array(got), want, rtol=3e-2, atol=3e-2)


def test_gemm_rejects_bad_shapes():
    with pytest.raises(AssertionError):
        mac_array.gemm(rand((4, 5), 0), rand((6, 4), 1))


# ------------------------------------------------- MAC-array CONV (im2col)


@given(
    c=st.integers(1, 8),
    k=st.integers(1, 8),
    hw=st.integers(4, 14),
    kern=st.sampled_from([1, 3, 5]),
    seed=st.integers(0, 2**16),
)
def test_mac_array_conv_matches_oracle(c, k, hw, kern, seed):
    x = rand((1, c, hw, hw), seed)
    w = rand((k, c, kern, kern), seed + 1)
    pad = kern // 2
    got = mac_array.conv2d(x, w, stride=1, padding=pad, bm=16, bk=16, bn=16)
    want = ref.conv2d(x, w, stride=1, padding=pad)
    assert_allclose(np.array(got), np.array(want), rtol=1e-3, atol=1e-3)


def test_mac_array_conv_stride2():
    x = rand((1, 4, 13, 13), 7)
    w = rand((6, 4, 3, 3), 8)
    got = mac_array.conv2d(x, w, stride=2, padding=1, bm=16, bk=16, bn=16)
    want = ref.conv2d(x, w, stride=2, padding=1)
    assert got.shape == want.shape
    assert_allclose(np.array(got), np.array(want), rtol=1e-3, atol=1e-3)


def test_im2col_reference_consistency():
    # The oracle's own two conv formulations agree.
    x = rand((2, 3, 9, 9), 9)
    w = rand((5, 3, 3, 3), 10)
    assert_allclose(
        np.array(ref.conv2d_via_im2col(x, w)),
        np.array(ref.conv2d(x, w)),
        rtol=1e-4,
        atol=1e-4,
    )


# -------------------------------------------------- pipeline-stage CONV


@given(
    c=st.integers(1, 6),
    k=st.integers(1, 6),
    h=st.integers(4, 12),
    w=st.integers(4, 14),
    kern=st.sampled_from([1, 3, 5]),
    stride=st.sampled_from([1, 2]),
    block_w=st.sampled_from([1, 3, 8]),
    seed=st.integers(0, 2**16),
)
def test_conv_stage_matches_oracle(c, k, h, w, kern, stride, block_w, seed):
    x = rand((1, c, h, w), seed)
    wt = rand((k, c, kern, kern), seed + 1)
    pad = kern // 2
    got = conv_stage.conv2d(x, wt, stride=stride, padding=pad, block_w=block_w)
    want = ref.conv2d(x, wt, stride=stride, padding=pad)
    assert got.shape == want.shape, (got.shape, want.shape)
    assert_allclose(np.array(got), np.array(want), rtol=1e-3, atol=1e-3)


def test_conv_stage_rejects_batch():
    x = rand((2, 3, 8, 8), 0)
    w = rand((4, 3, 3, 3), 1)
    with pytest.raises(AssertionError):
        conv_stage.conv2d(x, w)


def test_conv_stage_column_strip_boundaries():
    # Output width not divisible by block_w exercises the padded strip.
    x = rand((1, 3, 8, 10), 2)
    w = rand((4, 3, 3, 3), 3)
    got = conv_stage.conv2d(x, w, block_w=4)  # w_out=10, strips=3
    want = ref.conv2d(x, w)
    assert_allclose(np.array(got), np.array(want), rtol=1e-3, atol=1e-3)
