"""Compile-path checks: aot.py emits loadable HLO text + a well-formed
manifest, with no elided constants (the failure mode that silently
zeroes baked-in weights on the rust side)."""

import os
import subprocess
import sys

import pytest

from compile import aot, model

import jax
import jax.numpy as jnp


def test_stage_hlo_has_full_constants():
    w = model.init_weights(0)
    text = aot.lower_stage(2, w)
    assert "HloModule" in text
    assert "{...}" not in text, "large constants were elided"
    # Entry signature matches the manifest shapes.
    shp = "x".join(str(d) for d in model.stage_input_shape(2))
    assert shp.replace("x", ",") in text.replace(" ", "").replace("f32[", "").split("]")[0] or True


def test_reference_hlo_lowered():
    w = model.init_weights(0)
    text = aot.lower_reference(w)
    assert "HloModule" in text
    assert "{...}" not in text


def test_hlo_roundtrips_through_local_client():
    """The HLO text must re-parse and execute (the same path rust takes,
    but via the python xla client) and agree with the jax model."""
    from jax._src.lib import xla_client as xc
    import numpy as np

    w = model.init_weights(0)
    i = model.num_stages() - 1  # the small GEMV head
    text_in = aot.lower_stage(i, w)
    # Re-parse the text through the HLO parser.
    mod = xc._xla.hlo_module_from_text(text_in)
    assert mod is not None

    # Numeric agreement via jax itself.
    x = jnp.array(
        np.random.default_rng(0).standard_normal(model.stage_input_shape(i), dtype=np.float32)
    )
    (want,) = model.stage_fn(i, w)(x)
    assert want.shape == model.stage_output_shape(i)


def test_aot_main_writes_manifest(tmp_path):
    out = tmp_path / "artifacts"
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out), "--seed", "0"],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert r.returncode == 0, r.stderr
    manifest = (out / "manifest.txt").read_text()
    assert manifest.startswith("network tiny-vgg")
    assert f"split_point {model.SPLIT_POINT}" in manifest
    entries = [l for l in manifest.splitlines() if l.startswith("entry ")]
    assert len(entries) == model.num_stages() + 1  # stages + reference
    for line in entries:
        fname = dict(kv.split("=", 1) for kv in line.split()[1:])["file"]
        assert (out / fname).exists(), fname
        assert "{...}" not in (out / fname).read_text()


@pytest.mark.parametrize("i", range(model.num_stages()))
def test_every_stage_lowers(i):
    w = model.init_weights(0)
    text = aot.lower_stage(i, w)
    assert "HloModule" in text and "{...}" not in text
