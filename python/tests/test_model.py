"""L2 correctness: staged tiny-VGG (Pallas kernels) vs the pure-jnp
whole-model reference — proving the per-stage decomposition the rust
ChainExecutor will run is numerically identical to the monolith."""

import numpy as np
import jax.numpy as jnp
from numpy.testing import assert_allclose

from compile import model
from compile.kernels import ref


def rand_input(seed=0):
    return jnp.array(
        np.random.default_rng(seed).standard_normal(model.INPUT_SHAPE, dtype=np.float32)
    )


def test_staged_equals_reference():
    w = model.init_weights(0)
    x = rand_input(1)
    assert_allclose(
        np.array(model.staged_forward(x, w)),
        np.array(model.reference(x, w)),
        rtol=1e-4,
        atol=1e-4,
    )


def test_stage_shapes_chain():
    w = model.init_weights(0)
    cur = rand_input(2)
    for i in range(model.num_stages()):
        assert cur.shape == model.stage_input_shape(i), f"stage {i} input"
        (cur,) = model.stage_fn(i, w)(cur)
        assert cur.shape == model.stage_output_shape(i), f"stage {i} output"
    assert cur.shape == (1, model.NUM_CLASSES)


def test_split_point_roles():
    roles = [model.stage_role(i) for i in range(model.num_stages())]
    assert roles[: model.SPLIT_POINT] == ["pipeline_stage"] * model.SPLIT_POINT
    assert set(roles[model.SPLIT_POINT :]) == {"generic_layer"}


def test_weights_deterministic_by_seed():
    w1 = model.init_weights(42)
    w2 = model.init_weights(42)
    w3 = model.init_weights(43)
    for a, b in zip(w1, w2):
        assert_allclose(np.array(a), np.array(b))
    assert not np.allclose(np.array(w1[0]), np.array(w3[0]))


def test_reference_responds_to_input():
    w = model.init_weights(0)
    y1 = model.reference(rand_input(1), w)
    y2 = model.reference(rand_input(2), w)
    assert not np.allclose(np.array(y1), np.array(y2))


def test_relu_and_pool_present():
    # Activations after a stage are non-negative (relu fused per stage).
    w = model.init_weights(0)
    (y,) = model.stage_fn(0, w)(rand_input(3))
    assert float(jnp.min(y)) >= 0.0
    # Pooling halves spatial dims where configured.
    assert model.stage_output_shape(1)[2] == model.stage_input_shape(1)[2] // 2


def test_oracle_pool_and_gap():
    x = jnp.arange(16.0).reshape(1, 1, 4, 4)
    p = ref.maxpool2(x)
    assert p.shape == (1, 1, 2, 2)
    assert float(p[0, 0, 0, 0]) == 5.0
    g = ref.global_avg_pool(x)
    assert g.shape == (1, 1)
    assert float(g[0, 0]) == 7.5
