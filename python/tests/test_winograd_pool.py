"""Winograd F(2x2,3x3) and pooling Pallas kernels vs the oracle."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import pool, ref, winograd

settings.register_profile("ci2", max_examples=20, deadline=None)
settings.load_profile("ci2")


def rand(shape, seed):
    return jnp.array(np.random.default_rng(seed).standard_normal(shape, dtype=np.float32))


@given(
    c=st.integers(1, 6),
    k=st.integers(1, 6),
    h=st.integers(3, 13),
    w=st.integers(3, 13),
    seed=st.integers(0, 2**16),
)
def test_winograd_matches_direct_conv(c, k, h, w, seed):
    x = rand((1, c, h, w), seed)
    wt = rand((k, c, 3, 3), seed + 1)
    got = winograd.conv2d_3x3(x, wt)
    want = ref.conv2d(x, wt, stride=1, padding=1)
    assert got.shape == want.shape
    assert_allclose(np.array(got), np.array(want), rtol=1e-3, atol=1e-3)


def test_winograd_odd_sizes_cropped():
    x = rand((1, 3, 7, 9), 3)
    wt = rand((4, 3, 3, 3), 4)
    got = winograd.conv2d_3x3(x, wt)
    assert got.shape == (1, 4, 7, 9)
    want = ref.conv2d(x, wt)
    assert_allclose(np.array(got), np.array(want), rtol=1e-3, atol=1e-3)


def test_winograd_multiply_count_reduction():
    # F(2x2,3x3): 16 multiplies per 2x2 tile vs 36 direct = 2.25x —
    # the constant the rust HybridDNN baseline uses.
    direct = 4 * 9
    wino = 16
    assert direct / wino == 2.25


@given(
    c=st.integers(1, 8),
    h=st.sampled_from([2, 4, 6, 8, 16]),
    w=st.sampled_from([2, 4, 6, 10]),
    seed=st.integers(0, 2**16),
)
def test_maxpool_matches_oracle(c, h, w, seed):
    x = rand((1, c, h, w), seed)
    got = pool.maxpool2(x)
    want = ref.maxpool2(x)
    assert got.shape == want.shape
    assert_allclose(np.array(got), np.array(want), rtol=0, atol=0)


def test_maxpool_selects_maximum():
    x = jnp.arange(16.0).reshape(1, 1, 4, 4)
    got = pool.maxpool2(x)
    assert got.shape == (1, 1, 2, 2)
    assert np.array_equal(np.array(got)[0, 0], [[5.0, 7.0], [13.0, 15.0]])
