"""Quantized-datapath checks: the tiny-VGG survives the accelerator's
8/16-bit fixed-point precision (the paper's two operating points)."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import model
from compile.kernels import quant

settings.register_profile("ci3", max_examples=30, deadline=None)
settings.load_profile("ci3")


@given(
    bits=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**16),
    scale=st.floats(0.01, 100.0),
)
def test_quantization_error_bounded(bits, seed, scale):
    x = jnp.array(
        np.random.default_rng(seed).standard_normal(64, dtype=np.float32) * scale
    )
    q = quant.fake_quant(x, bits)
    s = float(quant.scale_for(x, bits))
    # Round-to-nearest: error per element <= scale/2.
    err = np.abs(np.array(q) - np.array(x)).max()
    assert err <= s / 2 + 1e-6, (err, s)


def test_codes_are_integers_in_range():
    x = jnp.array(np.linspace(-3.0, 3.0, 101, dtype=np.float32))
    codes, s = quant.quantize(x, 8)
    c = np.array(codes)
    assert np.allclose(c, np.round(c))
    assert c.max() <= 127 and c.min() >= -128
    assert s > 0


def test_zero_input_is_stable():
    x = jnp.zeros(16)
    q = quant.fake_quant(x, 8)
    assert np.array_equal(np.array(q), np.zeros(16))


def _logits(weights, seed):
    x = jnp.array(
        np.random.default_rng(seed).standard_normal(model.INPUT_SHAPE, dtype=np.float32)
    )
    return np.array(model.reference(x, weights))


def test_int16_model_matches_float_closely():
    w = model.init_weights(0)
    w16 = quant.quantize_weights(w, 16)
    for seed in range(3):
        a = _logits(w, seed)
        b = _logits(w16, seed)
        assert_allclose(a, b, rtol=5e-3, atol=5e-3)


def test_int8_model_preserves_top1():
    # 8-bit weights: logits move, but the argmax (the accelerator's
    # answer) stays put on most inputs.
    w = model.init_weights(0)
    w8 = quant.quantize_weights(w, 8)
    agree = 0
    n = 8
    for seed in range(n):
        a = _logits(w, seed)
        b = _logits(w8, seed)
        if int(a.argmax()) == int(b.argmax()):
            agree += 1
    assert agree >= n - 1, f"top-1 agreement {agree}/{n}"
