"""L2: the tiny-VGG network served by the end-to-end example, expressed
as the paper's accelerator would execute it.

The network (3x32x32 CIFAR-scale input, 10 classes):

  stage 0:  conv3x3(16) + relu                 — pipeline stage (L1 conv_stage)
  stage 1:  conv3x3(16) + relu + maxpool2      — pipeline stage
  layer 2:  conv3x3(32) + relu + maxpool2      — generic structure (L1 mac_array)
  layer 3:  conv3x3(64) + relu + maxpool2      — generic structure
  layer 4:  GAP + dense(10)                    — generic structure (GEMV)

The split point (SP = 2) mirrors the paper's paradigm: the first,
CTC-volatile high-resolution layers get dedicated stages; the rest run on
the reusable MAC array. Weights are synthetic (seeded) — see DESIGN.md's
substitution table.

Each ``stage_fn(i)`` closure takes only the activation tensor (weights are
baked in), which is exactly what ``aot.py`` lowers per stage and what the
rust ``ChainExecutor`` chains at serving time. ``reference(x)`` is the
whole-model oracle used to verify the chain composes correctly.
"""

import numpy as np
import jax.numpy as jnp

from .kernels import conv_stage, mac_array, ref

SPLIT_POINT = 2
INPUT_SHAPE = (1, 3, 32, 32)
NUM_CLASSES = 10

# (out_c, kernel, stride, pad, pool_after)
CONV_CFG = [
    (16, 3, 1, 1, False),
    (16, 3, 1, 1, True),
    (32, 3, 1, 1, True),
    (64, 3, 1, 1, True),
]


def init_weights(seed=0):
    """Synthetic trained parameters (seeded, He-scaled)."""
    rng = np.random.default_rng(seed)
    weights = []
    c_in = INPUT_SHAPE[1]
    for out_c, k, _, _, _ in CONV_CFG:
        fan_in = c_in * k * k
        w = rng.standard_normal((out_c, c_in, k, k)).astype(np.float32)
        weights.append(jnp.array(w * np.sqrt(2.0 / fan_in)))
        c_in = out_c
    wd = rng.standard_normal((c_in, NUM_CLASSES)).astype(np.float32)
    weights.append(jnp.array(wd * np.sqrt(2.0 / c_in)))
    return weights


def _apply_conv(i, x, w, conv_fn):
    out_c, k, stride, pad, pool = CONV_CFG[i]
    y = conv_fn(x, w, stride=stride, padding=pad)
    y = ref.relu(y)
    if pool:
        y = ref.maxpool2(y)
    return y


def stage_fn(i, weights):
    """The i-th accelerator stage as a single-activation-input closure.

    Stages ``0 .. SPLIT_POINT-1`` use the column-streamed pipeline kernel;
    the rest use the MAC-array (im2col GEMM) kernel; the final stage is
    the GAP + dense head on the MAC array's GEMV path.
    """
    n_conv = len(CONV_CFG)
    if i < n_conv:
        conv_fn = conv_stage.conv2d if i < SPLIT_POINT else mac_array.conv2d
        w = weights[i]

        def fn(x):
            return (_apply_conv(i, x, w, conv_fn),)

        return fn
    if i == n_conv:
        wd = weights[n_conv]

        def head(x):
            pooled = ref.global_avg_pool(x)  # (1, C)
            return (mac_array.gemm(pooled, wd, bm=8, bk=64, bn=16),)

        return head
    raise IndexError(i)


def num_stages():
    return len(CONV_CFG) + 1


def stage_role(i):
    """Manifest role of stage i."""
    return "pipeline_stage" if i < SPLIT_POINT else "generic_layer"


def staged_forward(x, weights):
    """Run all stages in sequence (what the rust ChainExecutor does)."""
    cur = x
    for i in range(num_stages()):
        (cur,) = stage_fn(i, weights)(cur)
    return cur


def reference(x, weights):
    """Whole-model oracle on pure-jnp ops (no Pallas)."""
    cur = x
    for i in range(len(CONV_CFG)):
        cur = _apply_conv(i, cur, weights[i], ref.conv2d)
    pooled = ref.global_avg_pool(cur)
    return ref.dense(pooled, weights[len(CONV_CFG)])


def stage_input_shape(i):
    """Activation shape entering stage i (batch 1)."""
    shape = list(INPUT_SHAPE)
    for j in range(min(i, len(CONV_CFG))):
        out_c, _, _, _, pool = CONV_CFG[j]
        shape[1] = out_c
        if pool:
            shape[2] //= 2
            shape[3] //= 2
    return tuple(shape)


def stage_output_shape(i):
    if i < len(CONV_CFG):
        return stage_input_shape(i + 1)
    return (1, NUM_CLASSES)
