"""AOT compile path: lower every accelerator stage of the tiny-VGG model
(plus a whole-model reference) to HLO **text** and write the artifact
manifest the rust runtime consumes.

HLO text — NOT ``.serialize()`` — is the interchange format: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Run once via ``make artifacts``; python never appears on the request path.

Usage: python -m compile.aot --out ../artifacts
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange).

    ``print_large_constants=True`` is essential: the baked-in stage
    weights are large f32 literals, and the default printer elides them
    as ``constant({...})`` — which the rust-side text parser would turn
    into zeros.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def lower_stage(i, weights):
    fn = model.stage_fn(i, weights)
    spec = jax.ShapeDtypeStruct(model.stage_input_shape(i), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(spec))


def lower_reference(weights):
    def fn(x):
        return (model.reference(x, weights),)

    spec = jax.ShapeDtypeStruct(model.INPUT_SHAPE, jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(spec))


def shape_str(shape):
    return "x".join(str(d) for d in shape)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    ap.add_argument("--seed", type=int, default=0, help="synthetic weight seed")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    weights = model.init_weights(args.seed)
    lines = [
        f"network tiny-vgg-{shape_str(model.INPUT_SHAPE[1:])}",
        f"split_point {model.SPLIT_POINT}",
    ]

    for i in range(model.num_stages()):
        text = lower_stage(i, weights)
        fname = f"stage{i}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        lines.append(
            "entry file={} role={} index={} in={} out={}".format(
                fname,
                model.stage_role(i),
                i,
                shape_str(model.stage_input_shape(i)),
                shape_str(model.stage_output_shape(i)),
            )
        )
        print(f"wrote {fname} ({len(text)} chars)")

    ref_text = lower_reference(weights)
    with open(os.path.join(args.out, "reference.hlo.txt"), "w") as f:
        f.write(ref_text)
    lines.append(
        "entry file=reference.hlo.txt role=reference_model in={} out=1x{}".format(
            shape_str(model.INPUT_SHAPE), model.NUM_CLASSES
        )
    )
    print(f"wrote reference.hlo.txt ({len(ref_text)} chars)")

    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"wrote manifest.txt ({len(lines)} lines)")


if __name__ == "__main__":
    main()
