"""L1 Pallas kernel: Winograd F(2x2, 3x3) convolution — the HybridDNN
baseline's fast-CONV mode (paper [2]), used by the ablation comparing
spatial vs Winograd PEs.

F(2x2, 3x3) computes each 2x2 output tile from a 4x4 input tile with 16
multiplies instead of 36 (the 2.25x reduction modeled by
``rust/src/baselines/hybriddnn.rs``). The transform matrices:

    B^T = [[1, 0, -1, 0], [0, 1, 1, 0], [0, -1, 1, 0], [0, 1, 0, -1]]
    G   = [[1, 0, 0], [.5, .5, .5], [.5, -.5, .5], [0, 0, 1]]
    A^T = [[1, 1, 1, 0], [0, 1, -1, -1]]

The Pallas kernel performs the element-wise multiply + channel reduction
(the EWMM core that maps to the MXU as 16 batched GEMMs); the small
B/G/A transforms stay in jnp (on the FPGA they are the LUT/DSP transform
units around the array — see WINOGRAD_ARRAY_FRACTION).

``interpret=True`` — see ``mac_array.py``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BT = jnp.array(
    [[1.0, 0.0, -1.0, 0.0], [0.0, 1.0, 1.0, 0.0], [0.0, -1.0, 1.0, 0.0], [0.0, 1.0, 0.0, -1.0]],
    jnp.float32,
)
G = jnp.array(
    [[1.0, 0.0, 0.0], [0.5, 0.5, 0.5], [0.5, -0.5, 0.5], [0.0, 0.0, 1.0]], jnp.float32
)
AT = jnp.array([[1.0, 1.0, 1.0, 0.0], [0.0, 1.0, -1.0, -1.0]], jnp.float32)


def _ewmm_kernel(u_ref, v_ref, m_ref):
    """Element-wise multiply matrix: for each of the 16 (xi, nu) tap
    positions, contract over input channels C.

    u_ref: (16, K, C) transformed weights; v_ref: (16, C, T) transformed
    input tiles; m_ref: (16, K, T).
    """
    u = u_ref[...]
    v = v_ref[...]
    m_ref[...] = jnp.einsum("pkc,pct->pkt", u, v)


@functools.partial(jax.jit, static_argnames=())
def conv2d_3x3(x, w):
    """Winograd F(2x2,3x3) CONV, stride 1, padding 1.

    ``x``: (1, C, H, W); ``w``: (K, C, 3, 3). H and W may be odd — the
    output is computed on the ceil-to-even grid and cropped (the tile
    quantization HybridDNN pays on odd feature maps).
    """
    n, c, h, wd = x.shape
    assert n == 1
    k_out = w.shape[0]
    h_out, w_out = h, wd  # stride 1, pad 1
    th, tw = -(-h_out // 2), -(-w_out // 2)  # 2x2 output tiles

    # Pad input so every 4x4 tile (stride 2) is in range: need
    # 2*th + 2 rows of padded input starting at -1.
    xp = jnp.pad(x[0], ((0, 0), (1, 2 * th + 3 - h - 1), (1, 2 * tw + 3 - wd - 1)))

    # Gather 4x4 input tiles at stride 2: (C, th, tw, 4, 4).
    tiles = jnp.stack(
        [
            jnp.stack(
                [xp[:, 2 * i : 2 * i + 4, 2 * j : 2 * j + 4] for j in range(tw)],
                axis=1,
            )
            for i in range(th)
        ],
        axis=1,
    )  # (C, th, tw, 4, 4)

    # Input transform: V = B^T d B per tile.
    v = jnp.einsum("ab,ctubd,de->ctuae", BT, tiles, BT.T)  # (C, th, tw, 4, 4)
    v = v.transpose(3, 4, 0, 1, 2).reshape(16, c, th * tw)

    # Weight transform: U = G g G^T.
    u = jnp.einsum("ab,kcbd,de->kcae", G, w, G.T)  # (K, C, 4, 4)
    u = u.transpose(2, 3, 0, 1).reshape(16, k_out, c)

    # EWMM on the Pallas MAC array.
    m = pl.pallas_call(
        _ewmm_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec(u.shape, lambda i: (0, 0, 0)),
            pl.BlockSpec(v.shape, lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((16, k_out, th * tw), lambda i: (0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((16, k_out, th * tw), jnp.float32),
        interpret=True,
    )(u, v)

    # Output transform: Y = A^T m A per tile.
    m = m.reshape(4, 4, k_out, th, tw)
    y = jnp.einsum("ab,bdktu,de->ktuae", AT, m, AT.T)  # (K, th, tw, 2, 2)
    y = y.transpose(0, 1, 3, 2, 4).reshape(k_out, 2 * th, 2 * tw)
    return y[None, :, :h_out, :w_out]
