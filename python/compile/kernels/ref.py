"""Pure-jnp reference oracle for the Pallas kernels and the L2 model.

Everything here is straight-line jax.numpy — no Pallas, no custom calls —
so it runs anywhere and serves as the correctness ground truth for:

* ``mac_array.gemm``      vs ``ref.matmul``
* ``mac_array.conv2d``    vs ``ref.conv2d``
* ``conv_stage.conv2d``   vs ``ref.conv2d``
* the staged tiny-VGG     vs ``ref`` forward composition

Layout conventions: activations are NCHW, weights are KCRS (out-channels,
in-channels, kernel-h, kernel-w) — matching the rust coordinator's
``HostTensor`` row-major buffers.
"""

import jax.numpy as jnp
from jax import lax


def matmul(a, b):
    """Plain f32 matrix multiply (the MAC-array GEMV/GEMM oracle)."""
    return jnp.matmul(a.astype(jnp.float32), b.astype(jnp.float32))


def conv2d(x, w, stride=1, padding=1):
    """NCHW x KCRS convolution with symmetric spatial padding."""
    return lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=((padding, padding), (padding, padding)),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def relu(x):
    return jnp.maximum(x, 0.0)


def maxpool2(x):
    """2x2/s2 max pooling over NCHW."""
    return lax.reduce_window(
        x,
        -jnp.inf,
        lax.max,
        window_dimensions=(1, 1, 2, 2),
        window_strides=(1, 1, 2, 2),
        padding="VALID",
    )


def global_avg_pool(x):
    """NCHW -> NC global average pool."""
    return jnp.mean(x, axis=(2, 3))


def dense(x, w):
    """NC x CK fully-connected layer."""
    return matmul(x, w)


def im2col(x, kernel, stride=1, padding=1):
    """Unfold NCHW input into (N, H_out*W_out, C*R*S) patches.

    This is the layout the generic structure's MAC array consumes: each
    output pixel becomes one GEMV against the (C*R*S, K) weight matrix.
    """
    n, c, h, w = x.shape
    xp = jnp.pad(x, ((0, 0), (0, 0), (padding, padding), (padding, padding)))
    h_out = (h + 2 * padding - kernel) // stride + 1
    w_out = (w + 2 * padding - kernel) // stride + 1
    patches = lax.conv_general_dilated_patches(
        xp,
        filter_shape=(kernel, kernel),
        window_strides=(stride, stride),
        padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )  # (N, C*R*S, H_out, W_out)
    patches = patches.reshape(n, c * kernel * kernel, h_out * w_out)
    return jnp.transpose(patches, (0, 2, 1)), (h_out, w_out)


def conv2d_via_im2col(x, w, stride=1, padding=1):
    """Reference conv built from im2col + matmul (the generic-structure
    dataflow, expressed with the oracle's own pieces)."""
    k_out, c, r, s = w.shape
    cols, (h_out, w_out) = im2col(x, r, stride, padding)
    wmat = w.reshape(k_out, c * r * s).T  # (C*R*S, K)
    out = jnp.einsum("npq,qk->npk", cols, wmat)
    out = jnp.transpose(out, (0, 2, 1)).reshape(x.shape[0], k_out, h_out, w_out)
    return out
