"""Fixed-point quantization utilities — the accelerator's datapath model.

The paper evaluates 16-bit and 8-bit fixed-point accelerators (Eq. 1's
α). This module models that datapath in jax: symmetric per-tensor
quantization to a `bits`-wide integer grid, used to (a) validate that the
tiny-VGG survives the accelerator's precision and (b) give the L2 model
an int8 export mode whose numerics the rust side can check.

The quantized values are *represented* in f32 (exact for |q| < 2^24), so
the same Pallas kernels execute the quantized network unchanged — just
like the FPGA's DSPs execute the same MACs on narrower operands.
"""

import jax.numpy as jnp


def scale_for(x, bits):
    """Symmetric per-tensor scale: max|x| mapped to the top code."""
    qmax = float(2 ** (bits - 1) - 1)
    amax = jnp.max(jnp.abs(x))
    return jnp.where(amax > 0, amax / qmax, 1.0)


def quantize(x, bits):
    """Quantize to the integer grid; returns (codes, scale).

    Codes are integers stored in f32: `x ≈ codes * scale`.
    """
    s = scale_for(x, bits)
    qmax = float(2 ** (bits - 1) - 1)
    codes = jnp.clip(jnp.round(x / s), -qmax - 1, qmax)
    return codes, s


def fake_quant(x, bits):
    """Quantize-dequantize: the value the accelerator actually computes
    with."""
    codes, s = quantize(x, bits)
    return codes * s


def quantize_weights(weights, bits):
    """Fake-quantize every tensor of a weight list (per-tensor scales)."""
    return [fake_quant(w, bits) for w in weights]
