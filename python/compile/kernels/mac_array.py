"""L1 Pallas kernel: the generic structure's reusable MAC array.

Hardware-adaptation note (DESIGN.md §Hardware-Adaptation): the paper's
generic structure is a ``CPF_g x KPF_g`` grid of FPGA DSP MACs computing
one GEMV per cycle, fed by BRAM ping-pong buffers. On a TPU-shaped target
the same insight — keep a weight tile stationary in fast memory and
stream activation vectors through it — maps to a *blocked GEMM* feeding
the MXU:

* the ``(CPF, KPF)`` unroll becomes the Pallas block shape ``(bk, bn)``;
* the feature-map / weight / accumulation BRAM buffers become VMEM blocks
  scheduled by ``BlockSpec`` index maps (HBM<->VMEM in place of
  DDR<->BRAM);
* the accumulation buffer's ping-pong is the f32 VMEM accumulator that
  persists across the ``k`` grid dimension.

CONV is expressed as im2col + GEMM — exactly the generic structure's
"one GEMV per output pixel" dataflow (paper §5.3.1).

``interpret=True`` everywhere: the CPU PJRT backend cannot execute Mosaic
custom calls; real-TPU performance is *estimated* in EXPERIMENTS.md §Perf
from the block shapes' VMEM footprint and MXU occupancy.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Default block shapes: 128 matches the MXU systolic dimension; the
# (bm, bk, bn) = (128, 128, 128) f32 working set is
# 3 * 128*128*4 B = 192 KiB of VMEM, comfortably inside a TPU core's
# ~16 MiB budget and leaving room for double buffering.
BLOCK_M = 128
BLOCK_K = 128
BLOCK_N = 128


def _gemm_kernel(a_ref, b_ref, o_ref):
    """One (bm, bn, k) grid step: accumulate a_ref @ b_ref into o_ref.

    The f32 output block doubles as the paper's accumulation buffer
    (§5.3.1): it is zeroed on the first k step and accumulated in place
    across the k grid dimension (the block index map pins the same
    output tile for every k, so the tile stays resident in VMEM — the
    ping-pong accumulation BRAM of the FPGA design).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...].astype(jnp.float32),
        b_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def _pad_to(x, m0, m1):
    """Zero-pad a 2-d array up to multiples of (m0, m1)."""
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def gemm(a, b, *, bm=BLOCK_M, bk=BLOCK_K, bn=BLOCK_N):
    """Blocked GEMM ``a @ b`` via the Pallas MAC-array kernel.

    Arbitrary (M, K) x (K, N) f32 inputs; internally padded to block
    multiples (the generic structure's G_fm/G_w group padding).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims differ: {k} vs {k2}"
    ap = _pad_to(a.astype(jnp.float32), bm, bk)
    bp = _pad_to(b.astype(jnp.float32), bk, bn)
    mp, kp = ap.shape
    _, np_ = bp.shape
    k_steps = kp // bk
    out = pl.pallas_call(
        _gemm_kernel,
        grid=(mp // bm, np_ // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(ap, bp)
    return out[:m, :n]


def conv2d(x, w, stride=1, padding=1, *, bm=BLOCK_M, bk=BLOCK_K, bn=BLOCK_N):
    """CONV on the generic structure: im2col + MAC-array GEMM.

    ``x``: NCHW activations, ``w``: KCRS weights. Matches ``ref.conv2d``.
    """
    n, _, _, _ = x.shape
    k_out, c, r, s = w.shape
    cols, (h_out, w_out) = ref.im2col(x, r, stride, padding)
    wmat = w.reshape(k_out, c * r * s).T  # (CRS, K)
    outs = []
    for i in range(n):
        outs.append(gemm(cols[i], wmat, bm=bm, bk=bk, bn=bn))
    out = jnp.stack(outs)  # (N, HW, K)
    out = jnp.transpose(out, (0, 2, 1)).reshape(n, k_out, h_out, w_out)
    return out
