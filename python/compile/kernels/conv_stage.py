"""L1 Pallas kernel: one dedicated pipeline stage (paper §5.2).

The FPGA pipeline stage computes a CONV layer with a ``(CPF_i, KPF_i)``
unroll fed by a *column buffer*: the stage starts as soon as the first
``S+1`` input columns are ready and walks the frame column by column
(DNNBuilder's fine-grained pipeline / column-based cache).

On the TPU-shaped target the column walk becomes the Pallas **grid over
output-column strips**: grid step ``j`` reads the input column window
``[j*bw .. j*bw + bw + S - 1]`` from HBM into VMEM (the column buffer)
and produces one output strip. The weight tensor is small per stage and
stays fully resident (the stage's weight buffer).

``interpret=True`` — see ``mac_array.py``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stage_kernel(x_ref, w_ref, o_ref, *, stride):
    """Compute one output-column strip.

    ``x_ref``: (1, C, H_pad, bw_in) input column window (already padded).
    ``w_ref``: (K, C, R, S) stage weights (fully resident).
    ``o_ref``: (K, H_out, bw) output strip.
    """
    x = x_ref[...][0].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    k_out, c, r, s = w.shape
    h_out = o_ref.shape[1]
    bw = o_ref.shape[2]

    # Unrolled kernel window: the (CPF x KPF) MAC array evaluates the
    # C-depth dot product for every (dy, dx) tap; taps accumulate.
    acc = jnp.zeros((k_out, h_out, bw), jnp.float32)
    for dy in range(r):
        for dx in range(s):
            # strided spatial slice of the column window
            xs = x[:, dy : dy + stride * h_out : stride, dx : dx + stride * bw : stride]
            # (K, C) x (C, h*bw) GEMM — the per-tap MAC-array step
            acc = acc + jnp.einsum("kc,chw->khw", w[:, :, dy, dx], xs)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("stride", "padding", "block_w"))
def conv2d(x, w, stride=1, padding=1, block_w=8):
    """Column-streamed CONV of one pipeline stage.

    ``x``: (1, C, H, W) activations; ``w``: (K, C, R, S) weights.
    ``block_w`` output columns are produced per grid step (the column
    buffer depth). Matches ``ref.conv2d``.
    """
    n, c, h, wdt = x.shape
    assert n == 1, "pipeline stages process one frame at a time"
    k_out, c2, r, s = w.shape
    assert c == c2, f"channel mismatch {c} vs {c2}"
    h_out = (h + 2 * padding - r) // stride + 1
    w_out = (wdt + 2 * padding - s) // stride + 1

    # Pad spatially; pad width further so w_out divides into strips.
    strips = -(-w_out // block_w)
    w_pad_extra = strips * block_w - w_out
    xp = jnp.pad(
        x[0],
        (
            (0, 0),
            (padding, padding),
            (padding, padding + w_pad_extra * stride),
        ),
    )  # (C, H_pad, W_pad)

    # Input window per strip: block_w output columns need
    # (block_w-1)*stride + s input columns.
    bw_in = (block_w - 1) * stride + s

    # Overlapping windows are awkward with pure BlockSpecs (block indices
    # are multiples of the block size); stage the windows explicitly —
    # still one HBM->VMEM copy per strip, which *is* the column-buffer
    # refill of the FPGA design.
    windows = jnp.stack(
        [
            jax.lax.dynamic_slice(
                xp,
                (0, 0, j * block_w * stride),
                (c, xp.shape[1], bw_in),
            )
            for j in range(strips)
        ]
    )  # (strips, C, H_pad, bw_in)

    out = pl.pallas_call(
        functools.partial(_stage_kernel, stride=stride),
        grid=(strips,),
        in_specs=[
            pl.BlockSpec(
                (1, c, xp.shape[1], bw_in), lambda j: (j, 0, 0, 0)
            ),
            pl.BlockSpec((k_out, c, r, s), lambda j: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((k_out, h_out, block_w), lambda j: (0, 0, j)),
        out_shape=jax.ShapeDtypeStruct((k_out, h_out, strips * block_w), jnp.float32),
        interpret=True,
    )(windows, w)
    return out[None, :, :, :w_out]
