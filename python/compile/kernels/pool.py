"""L1 Pallas kernel: 2x2/s2 max pooling — the functional sub-module of
the generic structure (paper §5.3: "a functional sub-module for
activation and pooling operations").

Grid over channels: each step reduces one channel plane in VMEM. On the
FPGA this unit sits behind the accumulation buffer; here it consumes the
CONV output block before it returns to HBM.

``interpret=True`` — see ``mac_array.py``.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pool_kernel(x_ref, o_ref):
    x = x_ref[...][0]  # (H, W)
    h2 = o_ref.shape[1]
    w2 = o_ref.shape[2]
    x = x[: 2 * h2, : 2 * w2]
    x = x.reshape(h2, 2, w2, 2)
    o_ref[...] = jnp.max(x, axis=(1, 3))[None]


@jax.jit
def maxpool2(x):
    """2x2/s2 max pool over NCHW (batch 1)."""
    n, c, h, w = x.shape
    assert n == 1, "pooling unit processes one frame at a time"
    h2, w2 = h // 2, w // 2
    out = pl.pallas_call(
        _pool_kernel,
        grid=(c,),
        in_specs=[pl.BlockSpec((1, h, w), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, h2, w2), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((c, h2, w2), jnp.float32),
        interpret=True,
    )(x[0].astype(jnp.float32))
    return out[None]
