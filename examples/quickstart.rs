//! Quickstart: explore an accelerator for VGG16 on a KU115 in ~a second,
//! then inspect what the DSE chose.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use dnnexplorer::dnn::{analysis, zoo, Precision, TensorShape};
use dnnexplorer::dse::pso::PsoParams;
use dnnexplorer::dse::{engine, ExplorerConfig};
use dnnexplorer::fpga::FpgaDevice;

fn main() -> anyhow::Result<()> {
    // 1. Pick a network and a board from the zoo / device catalogue.
    let net = zoo::vgg16_conv(TensorShape::new(3, 224, 224), Precision::Int16);
    let device = FpgaDevice::ku115();
    println!(
        "network: {} — {:.1} GOP, {} weights",
        net.name,
        net.total_gop(),
        net.total_weights()
    );

    // 2. Model analysis (the paper's step 1): layer-wise CTC profile.
    let dist = analysis::ctc_distribution(&net).expect("conv layers present");
    println!(
        "CTC distribution: min {:.0} / median {:.0} / max {:.0}",
        dist.min, dist.median, dist.max
    );
    let hs = analysis::half_split_variance(&net);
    println!("CTC variance first/second half: {:.1}x (paper Table 1)", hs.ratio());

    // 3. Two-level DSE (steps 2-3): PSO over the RAV + local optimizers.
    let cfg = ExplorerConfig {
        pso: PsoParams { population: 16, iterations: 15, ..Default::default() },
        ..ExplorerConfig::new(device)
    };
    let res = engine::explore(&net, &cfg).expect("feasible design");
    let b = &res.best;
    println!("\nbest RAV   : {}   (SP = split point, then DSP/BRAM/BW %)", b.rav);
    println!("throughput : {:.1} GOP/s ({:.1} img/s)", b.gops, b.throughput_fps);
    println!(
        "resources  : {:.0} DSP ({:.1}% efficient), {:.0} BRAM18K",
        b.dsp_used,
        b.dsp_efficiency * 100.0,
        b.bram_used
    );
    println!(
        "search     : {} iterations, {} evaluations, {:.2}s",
        res.stats.iterations, res.stats.evaluations, res.stats.elapsed_s
    );

    // 4. What the two structures look like.
    if let Some(p) = &b.pipeline {
        println!("\npipeline structure ({} stages):", p.config.stages.len());
        for (i, s) in p.config.stages.iter().enumerate() {
            println!("  stage {i}: CPF {} x KPF {}", s.cpf, s.kpf);
        }
    }
    if let Some(g) = &b.generic {
        println!(
            "generic structure: {}x{} MAC array, strategy {:?}",
            g.config.cpf, g.config.kpf, g.config.strategy
        );
    }
    Ok(())
}
