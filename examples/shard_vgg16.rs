//! Multi-FPGA sharding quickstart: is a pair of mid-range boards worth
//! more than one big one — and what does *replicating* a stage buy on
//! top of cutting?
//!
//! Partitions VGG16 across 2× ZCU102 (linked by 100 GbE-class serdes)
//! and compares the end-to-end model against a single VU9P running the
//! whole network — the classic scale-out vs scale-up question the shard
//! planner answers from the analytical models alone. Then re-plans the
//! pair with `max_replicas = 2`, letting the planner interleave frames
//! across both boards instead of (or as well as) cutting between them.
//!
//! ```sh
//! cargo run --release --example shard_vgg16
//! DNNEXPLORER_BENCH_FULL=1 cargo run --release --example shard_vgg16
//! ```

use dnnexplorer::dnn::{zoo, Precision, TensorShape};
use dnnexplorer::dse::cache::EvalCache;
use dnnexplorer::dse::multi::{compare_board_counts, compare_replication};
use dnnexplorer::dse::pso::PsoParams;
use dnnexplorer::report::tables;
use dnnexplorer::shard::{partition, ShardConfig};
use dnnexplorer::util::bench::full_mode;
use dnnexplorer::util::parallel::default_threads;
use dnnexplorer::FpgaDevice;

fn main() {
    let net = zoo::vgg16_conv(TensorShape::new(3, 224, 224), Precision::Int16);
    let cfg = ShardConfig {
        pso: if full_mode() {
            PsoParams::default()
        } else {
            PsoParams { population: 10, iterations: 8, ..PsoParams::default() }
        },
        threads: default_threads(),
        ..ShardConfig::default()
    };
    let cache = EvalCache::new();

    // Scale-out: 1 vs 2 ZCU102 boards over the default link.
    let cluster = vec![FpgaDevice::zcu102(), FpgaDevice::zcu102()];
    println!("exploring {} over 1..2x ZCU102 ({} link)...", net.name, cfg.link);
    let comparison = compare_board_counts(&net, &cluster, &cfg, &cache);
    println!("{}", tables::shard_comparison(&net.name, &comparison).render());
    let two_boards = comparison
        .outcomes
        .last()
        .and_then(|o| o.plan.as_ref())
        .expect("2-board partition feasible");
    print!("{}", two_boards.render());

    // Scale-up: one VU9P running the whole network (a 1-board "shard").
    let vu9p = partition(&net, &[FpgaDevice::vu9p()], &cfg, &cache)
        .expect("single VU9P feasible");
    println!(
        "\n2x ZCU102 sharded : {:>8.1} GOP/s ({:.1} img/s, {:.2} ms)",
        two_boards.gops,
        two_boards.throughput_fps,
        two_boards.latency_s * 1e3
    );
    println!(
        "1x VU9P monolith  : {:>8.1} GOP/s ({:.1} img/s, {:.2} ms)",
        vu9p.gops,
        vu9p.throughput_fps,
        vu9p.latency_s * 1e3
    );
    let ratio = two_boards.gops / vu9p.gops;
    println!(
        "verdict: two mid-range boards deliver {:.2}x the big board's throughput",
        ratio
    );

    // Interleave: the same pair, but stages may replicate across both
    // boards (round-robin frames, re-ordered on the way out). The
    // contiguous plans above are a subset of this search space, so the
    // replicated side never models worse — the question is the margin.
    let rep_cfg = ShardConfig { max_replicas: 2, ..cfg.clone() };
    let outcome = compare_replication(&net, &cluster, &rep_cfg, &cache);
    if let (Some(contig), Some(rep)) = (&outcome.contiguous, &outcome.replicated) {
        println!(
            "\nbest contiguous   : {:>8.1} GOP/s (bottleneck {})",
            contig.gops,
            contig.bottleneck()
        );
        println!(
            "best w/ replicas  : {:>8.1} GOP/s (max r = {}, bottleneck {})",
            rep.gops,
            rep.max_replication(),
            rep.bottleneck()
        );
        if let Some(gain) = outcome.gain() {
            println!("interleaving gain : {:.2}x", gain);
        }
        print!("{}", rep.render());
    }
    println!(
        "cache: {} design points, {} hits / {} misses",
        cache.len(),
        cache.hits(),
        cache.misses()
    );
}
