//! Reproduce Fig. 2b + Fig. 11: how the three paradigms scale as VGG-like
//! networks deepen from 13 to 38 CONV layers — the pure pipeline
//! (DNNBuilder) collapses, generic engines stay flat, and the hybrid
//! paradigm keeps the best of both.
//!
//! ```sh
//! cargo run --release --example deeper_dnns
//! ```

use dnnexplorer::report::{figures, Effort};
use dnnexplorer::util::bench::full_mode;

fn main() {
    let effort = if full_mode() { Effort::Full } else { Effort::Quick };
    println!("{}", figures::fig2b_depth_scaling(effort).render());
    println!("{}", figures::fig11_deeper_dnns(effort).render());
    println!("(paper: DNNExplorer delivers 4.2x DNNBuilder's throughput at 38 layers)");
}
