//! Reproduce the paper's Table 3 row-by-row: DNNExplorer accelerators for
//! VGG16 at all 12 input resolutions on KU115, batch = 1, plus the
//! Table 4 batch-free extension for the first 4 cases.
//!
//! ```sh
//! cargo run --release --example explore_vgg16          # quick search
//! DNNEXPLORER_BENCH_FULL=1 cargo run --release --example explore_vgg16
//! ```

use dnnexplorer::report::{tables, Effort};
use dnnexplorer::util::bench::full_mode;

fn main() {
    let effort = if full_mode() { Effort::Full } else { Effort::Quick };
    println!("{}", tables::table3_full_results(effort).render());
    println!("{}", tables::table4_batch_exploration(effort).render());
    println!("(paper reference: Table 3 / Table 4 — see EXPERIMENTS.md for the comparison)");
}
