//! Portfolio exploration: four networks × two devices in one invocation,
//! sharing one evaluation cache, with parallel swarm scoring.
//!
//! ```sh
//! cargo run --release --example explore_portfolio
//! DNNEXPLORER_BENCH_FULL=1 cargo run --release --example explore_portfolio
//! ```

use dnnexplorer::dnn::{zoo, Precision, TensorShape};
use dnnexplorer::dse::portfolio::{cross, explore_portfolio};
use dnnexplorer::dse::pso::PsoParams;
use dnnexplorer::util::bench::full_mode;
use dnnexplorer::util::parallel::default_threads;
use dnnexplorer::{ExplorerConfig, FpgaDevice};

fn main() {
    let p = Precision::Int16;
    let networks = vec![
        zoo::vgg16_conv(TensorShape::new(3, 224, 224), p),
        zoo::by_name("resnet18", 224, 224, p).expect("zoo"),
        zoo::by_name("yolo", 448, 448, p).expect("zoo"),
        zoo::by_name("alexnet", 227, 227, p).expect("zoo"),
    ];
    let devices = [FpgaDevice::ku115(), FpgaDevice::zc706()];

    let mut base = ExplorerConfig::new(FpgaDevice::ku115());
    base.pso = if full_mode() {
        PsoParams::default()
    } else {
        PsoParams { population: 12, iterations: 10, ..PsoParams::default() }
    };

    let threads = default_threads();
    let scenarios = cross(&networks, &devices, &base);
    println!(
        "exploring {} scenarios ({} networks x {} devices) on {} threads...",
        scenarios.len(),
        networks.len(),
        devices.len(),
        threads
    );
    let result = explore_portfolio(&scenarios, threads);
    print!("{}", result.render_table());
    if let Some(best) = result.best() {
        println!("winner: {}", best.label);
    }
}
