//! END-TO-END DRIVER: the full three-layer stack on a real workload.
//!
//! 1. DSE — explore an accelerator for the tiny-VGG model (the same
//!    network `python/compile/model.py` exports) on an embedded board,
//!    picking the split point and batch size.
//! 2. Runtime — load the AOT artifacts (Pallas kernels → jax → HLO text)
//!    through PJRT; verify the staged chain matches the whole-model
//!    reference executable numerically.
//! 3. Serving — run the coordinator with the explored batch size over a
//!    stream of requests from concurrent clients; report latency and
//!    throughput, plus the simulator's board-level estimate of the same
//!    configuration.
//!
//! Requires `make artifacts`. Results are recorded in EXPERIMENTS.md §E2E.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_inference
//! ```

use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use dnnexplorer::coordinator::{AcceleratorServer, BatcherConfig};
use dnnexplorer::dnn::graph::NetworkBuilder;
use dnnexplorer::dnn::{Network, Precision, TensorShape};
use dnnexplorer::dse::pso::PsoParams;
use dnnexplorer::dse::{engine, ExplorerConfig};
use dnnexplorer::fpga::FpgaDevice;
use dnnexplorer::runtime::executable::{ChainExecutor, HostTensor};
use dnnexplorer::runtime::{ArtifactStore, Engine};
use dnnexplorer::sim::{simulate_pipeline, trace::Trace, DramModel};

/// The tiny-VGG of `python/compile/model.py`, as an IR Network (must be
/// kept in sync with CONV_CFG there).
fn tiny_vgg() -> Network {
    NetworkBuilder::new("tiny-vgg", TensorShape::new(3, 32, 32), Precision::Int16)
        .conv(16, 3, 1, 1)
        .conv(16, 3, 1, 1)
        .pool(2, 2)
        .conv(32, 3, 1, 1)
        .pool(2, 2)
        .conv(64, 3, 1, 1)
        .pool(2, 2)
        .fc(10)
        .build()
}

fn main() -> anyhow::Result<()> {
    // ---------- 1. DSE ----------
    let net = tiny_vgg();
    let device = FpgaDevice::zc706();
    let cfg = ExplorerConfig {
        fixed_batch: None, // let the DSE pick the batch (Table 4 mode)
        pso: PsoParams { population: 16, iterations: 12, ..Default::default() },
        ..ExplorerConfig::new(device.clone())
    };
    let res = engine::explore(&net, &cfg).expect("feasible design");
    let best = &res.best;
    println!("== 1. DSE ({} on {}) ==", net.name, device.name);
    println!("best RAV: {}  ->  {:.1} GOP/s, {:.0} img/s (analytical)", best.rav, best.gops, best.throughput_fps);

    // Board-level (simulated) check of the pipeline part.
    if let Some(p) = &best.pipeline {
        let layers: Vec<_> = net.layers.iter().filter(|l| l.is_compute()).collect();
        let dram = DramModel::new(
            device.bandwidth_gbps * best.rav.bw_frac,
            device.freq_mhz,
        );
        let sim = simulate_pipeline(
            &layers[..best.rav.sp.min(p.config.stages.len())],
            &p.config,
            &dram,
            &mut Trace::disabled(),
        )?;
        println!(
            "pipeline part simulated: {:.0} fps (analytical {:.0} fps)",
            sim.fps, p.estimate.throughput_fps
        );
    }

    // ---------- 2. Runtime: load + verify the AOT chain ----------
    println!("\n== 2. PJRT runtime ==");
    let dir = std::env::var("DNNEXPLORER_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"));
    let store = ArtifactStore::open(&dir)?;
    let engine_px = Engine::cpu()?;
    println!("PJRT platform: {}", engine_px.platform());
    let chain = ChainExecutor::load(&engine_px, &store)?;
    let reference = engine_px.load_entry(&store, store.unique("reference_model")?)?;
    println!(
        "loaded {}: {} stages (split point {}), input {:?}",
        store.manifest.network,
        chain.stage_count(),
        store.manifest.split_point,
        chain.input_shape()
    );
    let mut frame = HostTensor::zeros(chain.input_shape());
    for (j, v) in frame.data.iter_mut().enumerate() {
        *v = ((j * 2654435761) % 1000) as f32 / 1000.0 - 0.5;
    }
    let staged = chain.run_frame(&frame)?;
    let whole = &reference.run(std::slice::from_ref(&frame))?[0];
    let max_err = staged
        .data
        .iter()
        .zip(&whole.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("staged chain vs reference model: max |err| = {max_err:.2e}");
    anyhow::ensure!(max_err < 1e-3, "chain does not reproduce the reference");

    // ---------- 3. Serving ----------
    println!("\n== 3. Serving (batch = {} from the RAV) ==", best.rav.batch);
    let batch = best.rav.batch.max(1);
    let input_shape = chain.input_shape().to_vec();
    drop(chain);
    drop(reference);
    let server = AcceleratorServer::spawn(
        move || {
            let engine = Engine::cpu()?;
            ChainExecutor::load(&engine, &store)
        },
        BatcherConfig { batch_size: batch, max_wait: Duration::from_millis(2) },
    )?;
    let requests = 256usize;
    let t = Instant::now();
    let mut clients = Vec::new();
    for i in 0..requests {
        let h = server.handle();
        let shape = input_shape.clone();
        clients.push(std::thread::spawn(move || {
            let mut f = HostTensor::zeros(&shape);
            for (j, v) in f.data.iter_mut().enumerate() {
                *v = ((i * 131 + j * 7) % 255) as f32 / 255.0;
            }
            h.infer(f).is_ok()
        }));
    }
    let ok = clients
        .into_iter()
        .map(|c| c.join().unwrap_or(false))
        .filter(|x| *x)
        .count();
    let dt = t.elapsed().as_secs_f64();
    println!(
        "{ok}/{requests} ok in {dt:.2}s = {:.1} req/s",
        requests as f64 / dt
    );
    println!("metrics: {}", server.metrics.summary());
    anyhow::ensure!(ok == requests, "some requests failed");
    anyhow::ensure!(
        server.metrics.errors.load(Ordering::Relaxed) == 0,
        "executor errors"
    );
    server.shutdown();
    println!("\nE2E OK: DSE -> artifacts -> PJRT chain -> batched serving");
    Ok(())
}
