//! OVERLOAD DEMO: what the serving coordinator does when offered more
//! load than the explored accelerator can sustain.
//!
//! The paper's paradigm wins on sustained throughput (up to 4.2x GOP/s
//! over pipeline-only baselines); this example shows the serving layer
//! holding that throughput under 2x-capacity open-loop load instead of
//! collapsing: a bounded admission queue sheds the excess with typed
//! errors while the workers keep running full batches.
//!
//! Runs three overload policies over the same synthetic pool:
//! * `Block`     — backpressure: the submitter is throttled, nothing shed.
//! * `Reject`    — newcomers get `ServeError::Overloaded` immediately.
//! * `ShedOldest`— freshest-first: waiting requests are evicted.
//!
//! ```sh
//! cargo run --release --example serve_overload
//! ```

use std::time::{Duration, Instant};

use dnnexplorer::coordinator::synthetic::FixedServiceModel;
use dnnexplorer::coordinator::{BatcherConfig, OverloadPolicy, QueueConfig, Router, ServeError};
use dnnexplorer::runtime::executable::HostTensor;

struct Outcome {
    ok: u64,
    shed: u64,
    failed: u64,
    elapsed: Duration,
    p99_us: u64,
    depth_max: u64,
}

fn drive(policy: OverloadPolicy, requests: usize) -> anyhow::Result<Outcome> {
    const WORKERS: usize = 2;
    const CAPACITY: usize = 16;
    let per_frame = Duration::from_micros(500);
    let router = Router::spawn_with(
        WORKERS,
        move || Ok(FixedServiceModel { per_frame }),
        QueueConfig {
            batch: BatcherConfig { batch_size: 4, max_wait: Duration::from_millis(2) },
            capacity: CAPACITY,
            policy,
            ..QueueConfig::default()
        },
    )?;

    // Offer 2x the pool's frame rate, open loop (absolute-time pacing,
    // so slow submissions are caught up with bursts, not forgotten).
    let capacity_fps = WORKERS as f64 / per_frame.as_secs_f64();
    let rate_hz = 2.0 * capacity_fps;
    let h = router.handle();
    let start = Instant::now();
    let mut pending = Vec::with_capacity(requests);
    let mut shed = 0u64;
    for i in 0..requests {
        let target = start + Duration::from_secs_f64(i as f64 / rate_hz);
        if let Some(d) = target.checked_duration_since(Instant::now()) {
            std::thread::sleep(d);
        }
        match h.submit_frame(HostTensor::new(vec![i as f32], vec![1])?) {
            Ok(rx) => pending.push(rx),
            Err(ServeError::Overloaded) => shed += 1,
            Err(e) => anyhow::bail!("unexpected admission error: {e}"),
        }
    }
    let mut ok = 0u64;
    let mut failed = 0u64;
    for rx in pending {
        // Bounded wait: a hung request should abort the demo, not wedge it.
        match rx.recv_timeout(Duration::from_secs(60)) {
            Ok(Ok(_)) => ok += 1,
            Ok(Err(_)) => failed += 1,
            Err(_) => anyhow::bail!("admitted request never resolved within 60s"),
        }
    }
    let elapsed = start.elapsed();
    let m = router.metrics.clone();
    router.shutdown();
    // Under ShedOldest the evictions surface on the response channels
    // (counted in `failed` above) and in the shed counter.
    anyhow::ensure!(
        m.accounted() == m.requests.load(std::sync::atomic::Ordering::Relaxed),
        "accounting must reconcile: {}",
        m.summary()
    );
    Ok(Outcome {
        ok,
        shed: m.shed.load(std::sync::atomic::Ordering::Relaxed),
        failed,
        elapsed,
        p99_us: m.latency_percentile_us(0.99),
        depth_max: m.queue_depth_max(),
    })
}

fn main() -> anyhow::Result<()> {
    let requests = 400;
    println!("== 2x-capacity open-loop load, 400 requests, queue bound 16 ==");
    println!(
        "{:<11} {:>6} {:>6} {:>8} {:>10} {:>10} {:>10}",
        "policy", "ok", "shed", "failed", "goodput/s", "p99(us)", "depth max"
    );
    for policy in [OverloadPolicy::Block, OverloadPolicy::Reject, OverloadPolicy::ShedOldest] {
        let o = drive(policy, requests)?;
        println!(
            "{:<11} {:>6} {:>6} {:>8} {:>10.0} {:>10} {:>10}",
            format!("{policy:?}"),
            o.ok,
            o.shed,
            o.failed,
            o.ok as f64 / o.elapsed.as_secs_f64(),
            o.p99_us,
            o.depth_max,
        );
    }
    println!(
        "\nBlock throttles the client (no shed, offered rate sags to capacity);\n\
         Reject keeps latency flat by refusing overflow at admission;\n\
         ShedOldest trades old waiters for fresh ones (freshest-first under burst)."
    );
    Ok(())
}
