//! Topology-aware sharding quickstart: the same four boards, three
//! different wirings — what does the interconnect choice cost, and what
//! does *planning for it* recover?
//!
//! Partitions VGG16 across 4× ZCU102 under three fabrics:
//!
//! * `p2p`  — a dedicated cable per cut (the optimistic classic model);
//! * `ring` — boards chained in slot order: every cut collapses to one
//!   boundary segment, hop latency grows with replica span;
//! * `star` — per-board uplinks into a switch with finite bisection
//!   bandwidth shared by all concurrent cut traffic.
//!
//! For the star it also runs the topology-awareness comparison: the
//! p2p-planned ("blind") structure re-priced on the switch against the
//! plan the fabric-aware DP picks — the gap is what ignoring the
//! interconnect costs at deployment.
//!
//! ```sh
//! cargo run --release --example shard_topology
//! DNNEXPLORER_BENCH_FULL=1 cargo run --release --example shard_topology
//! ```

use dnnexplorer::dnn::{zoo, Precision, TensorShape};
use dnnexplorer::dse::cache::EvalCache;
use dnnexplorer::dse::multi::compare_topology_awareness;
use dnnexplorer::dse::pso::PsoParams;
use dnnexplorer::shard::{partition, ShardConfig};
use dnnexplorer::sim::shard::{simulate_shard, ShardSimSpec};
use dnnexplorer::topo::FabricKind;
use dnnexplorer::util::bench::full_mode;
use dnnexplorer::util::parallel::default_threads;
use dnnexplorer::FpgaDevice;

fn main() {
    let net = zoo::vgg16_conv(TensorShape::new(3, 224, 224), Precision::Int16);
    let base = ShardConfig {
        pso: if full_mode() {
            PsoParams::default()
        } else {
            PsoParams { population: 10, iterations: 8, ..PsoParams::default() }
        },
        threads: default_threads(),
        max_replicas: 2,
        ..ShardConfig::default()
    };
    let cluster = vec![FpgaDevice::zcu102(); 4];
    let cache = EvalCache::new();

    // One cluster, three wirings. The star's bisection is deliberately
    // modest (4 GB/s shared) so concurrent cuts actually contend.
    let fabrics = [
        FabricKind::PointToPoint,
        FabricKind::Ring,
        FabricKind::Star { bisection_gbps: 4.0 },
    ];
    println!(
        "{} over 4x ZCU102 ({} per-port link), planned per fabric:\n",
        net.name, base.link
    );
    println!(
        "{:<10} {:>9} {:>9} {:>12} {:>7} {:>12}",
        "fabric", "img/s", "GOP/s", "latency", "max r", "bottleneck"
    );
    for fabric in fabrics {
        let cfg = ShardConfig { fabric, ..base.clone() };
        let plan = partition(&net, &cluster, &cfg, &cache).expect("feasible");
        // Cross-check the analytic number with the discrete-event walk.
        let sim = simulate_shard(&ShardSimSpec::from_plan(&plan), 600, 100).expect("simulates");
        println!(
            "{:<10} {:>9.1} {:>9.1} {:>9.2} ms {:>7} {:>12}   (sim {:.1} img/s)",
            format!("{fabric}"),
            plan.throughput_fps,
            plan.gops,
            plan.latency_s * 1e3,
            plan.max_replication(),
            plan.bottleneck(),
            sim.throughput_fps,
        );
    }

    // What does *knowing* the topology buy on the constrained switch?
    let starved = ShardConfig {
        fabric: FabricKind::Star { bisection_gbps: 0.5 },
        ..base.clone()
    };
    let outcome = compare_topology_awareness(&net, &cluster, &starved, &cache);
    if let (Some(blind), Some(aware)) = (&outcome.blind, &outcome.aware) {
        println!("\ntopology awareness on a starved star ({}):", starved.fabric);
        println!(
            "  blind (p2p-planned, deployed on the star): {:>8.1} img/s, {} through the switch",
            blind.throughput_fps,
            format!("{:.0} KB/frame", blind.cut_bytes().iter().sum::<f64>() / 1024.0),
        );
        println!(
            "  aware (fabric-priced DP):                  {:>8.1} img/s, {} through the switch",
            aware.throughput_fps,
            format!("{:.0} KB/frame", aware.cut_bytes().iter().sum::<f64>() / 1024.0),
        );
        if let Some(gain) = outcome.gain() {
            println!("  awareness gain: {gain:.2}x");
        }
        print!("\n{}", aware.render());
    }
    println!(
        "cache: {} design points, {} hits / {} misses",
        cache.len(),
        cache.hits(),
        cache.misses()
    );
}
